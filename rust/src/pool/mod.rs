//! Shared memory-budgeted K/V cache pool with LRU eviction and compressed
//! disk spill — the deployment tier the paper's §4.3/§5.2 memory-saving
//! claims need once more than one sequence is live at a time.
//!
//! # Budget model
//!
//! Every cached byte is in exactly one of three states, accounted exactly:
//!
//! * **hot** — the raw bytes of the page currently being appended to, per
//!   (sequence, layer). Hot pages are pinned: they cannot be evicted.
//! * **sealed** — entropy-coded pages resident in memory. These are the
//!   only evictable bytes.
//! * **spilled** — sealed pages whose encoded bytes were moved to the
//!   [`SpillFile`] on disk. They cost no memory and are reloaded (and
//!   CRC-verified) on demand.
//!
//! The configured budget bounds `hot + sealed`. Headroom is reserved
//! *before* any byte enters memory — eviction runs first, then the gauge is
//! bumped — so the in-memory high-water mark ([`PoolCounters`]) can only
//! exceed the budget when there was genuinely nothing left to evict (e.g.
//! the hot working set alone is larger than the budget). "Zero budget
//! violations" is therefore checkable as `high_water <= budget`. Bytes an
//! evictor frees are *credited to that evictor* and settled against its
//! reservation in a single locked step, so concurrent reservations can
//! never race freed headroom away from the thread that did the evicting.
//! A sealed page retired while a live snapshot still pins it is **not**
//! credited: its bytes stay physically resident in the epoch stash (below)
//! and keep charging the budget until the last pinned reader releases them,
//! so the high-water proof stays honest under lock-free readers.
//!
//! # Reads: snapshots and epochs
//!
//! Reads go through [`SharedKvPool::snapshot`], which returns a
//! [`KvSnapshot`] — a cheap, `Clone + Send`, point-in-time view of one
//! sequence. Taking the snapshot holds the sequence lock once (reloading
//! any spilled pages and capturing `Arc`s of the immutable sealed pages
//! plus their dictionary tables); every read on the handle after that is
//! **lock-free**: entropy decode touches only the captured `Arc`s.
//!
//! Eviction never blocks or invalidates a reader, RustDB-`pstore` style:
//! each snapshot **pins** the pool epoch at creation. When the evictor
//! retires a sealed page that a snapshot still references (`Arc` strong
//! count > 1 under the victim's sequence lock), it bumps the epoch and
//! parks the page in a time-stamped **stash** instead of freeing it. A
//! stash entry is reclaimed — and only then credited back to the budget —
//! once no live pin predates its retirement epoch (pin → retire →
//! reclaim). `pool.epoch_lag` gauges how far the oldest pin trails the
//! current epoch.
//!
//! # Concurrency
//!
//! Per-sequence caches live behind their own mutexes, so codec work
//! (sealing on append, snapshot materialization) for different sequences
//! runs genuinely in parallel; a single ledger mutex serializes the cheap
//! parts (byte accounting, LRU ordering, spill-slot extents, the stash).
//! Lock order is `sequence -> readers -> ledger` (a DAG); eviction, which
//! needs a *victim's* sequence lock while scanning under the ledger, only
//! ever `try_lock`s it and skips busy victims, so no cycle — and no
//! deadlock — is possible.
//!
//! Spill-file **I/O runs outside the ledger mutex**: the ledger only hosts
//! the extent allocator ([`SpillFile`]), which hands out positioned
//! read/write tickets against a shared [`SpillIo`] handle. An eviction
//! reserves its extent under the ledger, releases it, `pwrite`s the record,
//! then re-locks to publish the slot; a reload locates its extent under the
//! ledger and `pread`s + CRC-checks outside it. Reloads and evictions of
//! different sequences therefore overlap on disk instead of serializing —
//! see `concurrent_reloads_overlap_off_the_ledger` in the tests, which
//! asserts the overlap via the spill file's read-concurrency high-water
//! mark. In-flight pages stay consistent because the victim's (or
//! reader's) *sequence* lock is held across the whole transition.
//!
//! # Spill layout
//!
//! A spilled page record is [`SealedPage::serialize`] — raw length, element
//! count, dictionary version, then each encoded stream in the standard
//! [`crate::codec::EncodedStream`] wire framing — stored in a slot of the
//! [`SpillFile`] with its CRC-32 verified on every reload. Dictionary
//! tables are never dropped, so a page sealed against dictionary version
//! `v` decodes bit-exactly no matter how many evict/reload round trips it
//! survives. The framing is backend-agnostic: pages sealed with Huffman,
//! rANS, or mixed per-stream backends spill and reload identically.

mod counters;
mod spill;

pub use counters::PoolCounters;
pub use spill::{SpillFile, SpillIo};

use crate::error::{Error, Result};
use crate::kvcache::{
    KvCacheConfig, KvCacheStats, LayerSnapshot, PagedKvCache, SealedPage, SpilledHandle,
};
use crate::obs::{Counter, Gauge, Registry};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// (sequence, layer, page index) — stable identity of a sealed page.
type PageKey = (u64, usize, usize);

/// Pool construction options.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Per-sequence cache geometry and codec settings.
    pub cache: KvCacheConfig,
    /// In-memory budget for hot + sealed bytes (`None` = unbounded).
    pub budget_bytes: Option<u64>,
    /// Spill-file location; `None` uses a self-cleaning temp file.
    pub spill_path: Option<PathBuf>,
}

impl PoolConfig {
    /// Unbounded pool with a temp spill file.
    pub fn new(cache: KvCacheConfig) -> Self {
        PoolConfig { cache, budget_bytes: None, spill_path: None }
    }

    /// Builder-style byte-budget override.
    pub fn with_budget(mut self, bytes: u64) -> Self {
        self.budget_bytes = Some(bytes);
        self
    }

    /// Builder-style spill-file location override.
    pub fn with_spill_path(mut self, path: PathBuf) -> Self {
        self.spill_path = Some(path);
        self
    }
}

/// A sealed page the evictor retired while a live [`KvSnapshot`] still
/// pinned it. The bytes stay resident (and budget-charged) until every pin
/// predating `retired_at` is released; then the entry is dropped and its
/// bytes credited back.
#[derive(Debug)]
struct StashEntry {
    /// Keeps the page allocation alive; never handed out again (restores
    /// build a fresh `Arc`), only dropped at reclaim.
    #[allow(dead_code)]
    page: Arc<SealedPage>,
    bytes: u64,
    retired_at: u64,
}

/// Everything the cheap single mutex protects: the sequence registry, the
/// LRU ordering, the epoch stash, and the spill-slot allocator (extents +
/// directory — the disk I/O itself happens outside, on the shared
/// [`SpillIo`] handle).
#[derive(Debug)]
struct Ledger {
    seqs: BTreeMap<u64, Arc<Mutex<PagedKvCache>>>,
    /// Eviction order: tick -> page. Smallest tick = coldest.
    lru: BTreeMap<u64, PageKey>,
    /// Inverse of `lru` for touch/remove.
    tick_of: BTreeMap<PageKey, u64>,
    /// Pages with a live disk copy (resident *or* spilled): re-evicting a
    /// reloaded page costs no second write.
    slot_of: BTreeMap<PageKey, u64>,
    clock: u64,
    spill: SpillFile,
    /// Pages retired while snapshot-pinned, awaiting epoch reclaim.
    stash: Vec<StashEntry>,
}

impl Ledger {
    fn touch(&mut self, key: PageKey) {
        if let Some(old) = self.tick_of.remove(&key) {
            self.lru.remove(&old);
        }
        self.clock += 1;
        self.lru.insert(self.clock, key);
        self.tick_of.insert(key, self.clock);
    }

    fn untrack(&mut self, key: &PageKey) {
        if let Some(old) = self.tick_of.remove(key) {
            self.lru.remove(&old);
        }
    }
}

/// The shared, budgeted, spilling K/V cache pool. Cheap to share: clone the
/// [`Arc`] returned by [`SharedKvPool::new`] into every worker thread.
#[derive(Debug)]
pub struct SharedKvPool {
    config: KvCacheConfig,
    budget: Option<u64>,
    ledger: Mutex<Ledger>,
    /// Per-layer exponent bytes applied to every new sequence cache
    /// ("precomputed dictionaries", §3.3).
    training: Mutex<Vec<Vec<u8>>>,
    /// Scoped metric registry: each pool owns its own so the budget tests'
    /// exact per-pool assertions can never see another pool's traffic. The
    /// registry is **authoritative** — [`counters`](Self::counters) is a
    /// typed view built from its snapshot. The handles below are fetched
    /// from it once at construction.
    registry: Registry,
    in_memory: Arc<Gauge>,
    evictions: Arc<Counter>,
    spills: Arc<Counter>,
    reloads: Arc<Counter>,
    snapshots: Arc<Counter>,
    snapshot_reads: Arc<Counter>,
    stash_bytes: Arc<Gauge>,
    stash_reclaims: Arc<Counter>,
    epoch_lag: Arc<Gauge>,
    /// Monotone retirement clock: bumped every time a pinned page is
    /// stashed. Snapshots pin the value current at creation.
    epoch: AtomicU64,
    /// Pinned epoch -> live snapshot count. Its own small mutex (lock order
    /// `sequence -> readers -> ledger`).
    readers: Mutex<BTreeMap<u64, usize>>,
    /// Cached smallest pinned epoch (`u64::MAX` when no reader is live), so
    /// retire/reclaim read it without the `readers` lock.
    min_pinned: AtomicU64,
}

impl SharedKvPool {
    /// Create a pool.
    pub fn new(config: PoolConfig) -> Result<Arc<Self>> {
        let registry = Registry::new();
        let spill = match &config.spill_path {
            Some(p) => SpillFile::create(p, &registry)?,
            None => SpillFile::temp(&registry)?,
        };
        let in_memory = registry.gauge("pool.in_memory_bytes");
        let evictions = registry.counter("pool.evictions_total");
        let spills = registry.counter("pool.spills_total");
        let reloads = registry.counter("pool.reloads_total");
        let snapshots = registry.counter("pool.snapshots_total");
        let snapshot_reads = registry.counter("pool.snapshot_reads_total");
        let stash_bytes = registry.gauge("pool.stash_bytes");
        let stash_reclaims = registry.counter("pool.stash_reclaimed_pages_total");
        let epoch_lag = registry.gauge("pool.epoch_lag");
        Ok(Arc::new(SharedKvPool {
            config: config.cache,
            budget: config.budget_bytes,
            ledger: Mutex::new(Ledger {
                seqs: BTreeMap::new(),
                lru: BTreeMap::new(),
                tick_of: BTreeMap::new(),
                slot_of: BTreeMap::new(),
                clock: 0,
                spill,
                stash: Vec::new(),
            }),
            training: Mutex::new(Vec::new()),
            registry,
            in_memory,
            evictions,
            spills,
            reloads,
            snapshots,
            snapshot_reads,
            stash_bytes,
            stash_reclaims,
            epoch_lag,
            epoch: AtomicU64::new(0),
            readers: Mutex::new(BTreeMap::new()),
            min_pinned: AtomicU64::new(u64::MAX),
        }))
    }

    /// The pool's scoped metric registry — the one metrics surface. Budget
    /// and LRU state (`pool.in_memory_bytes`, `pool.evictions_total`,
    /// `pool.spills_total`, `pool.reloads_total`), spill traffic
    /// (`pool.spilled_bytes`, `pool.spill_bytes_written_total`,
    /// `pool.spill_bytes_read_total`, `pool.spill_read_concurrency`), and
    /// the snapshot read path (`pool.snapshots_total`,
    /// `pool.snapshot_reads_total`, `pool.stash_bytes`,
    /// `pool.stash_reclaimed_pages_total`, `pool.epoch_lag`). Snapshot it
    /// and [`merge`](crate::obs::Snapshot::merge) into the global snapshot
    /// for export; [`counters`](Self::counters) is a typed view over the
    /// same snapshot.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Cache geometry shared by every sequence in the pool.
    pub fn config(&self) -> &KvCacheConfig {
        &self.config
    }

    /// The configured in-memory budget.
    pub fn budget_bytes(&self) -> Option<u64> {
        self.budget
    }

    /// Record per-layer exponent training bytes; applied to all existing
    /// and future sequence caches.
    pub fn train_dictionaries(&self, per_layer_exponents: &[Vec<u8>]) -> Result<()> {
        {
            let mut t = self.training.lock().unwrap();
            *t = per_layer_exponents.to_vec();
        }
        let arcs: Vec<Arc<Mutex<PagedKvCache>>> =
            self.ledger.lock().unwrap().seqs.values().cloned().collect();
        for arc in arcs {
            let mut c = arc.lock().unwrap();
            for (layer, bytes) in per_layer_exponents.iter().enumerate() {
                c.dictionaries().train(layer, bytes)?;
            }
        }
        Ok(())
    }

    /// Fetch the cache for `seq`, creating it (and pre-training its
    /// dictionaries) on first use.
    fn seq_cache_or_create(&self, seq: u64) -> Result<Arc<Mutex<PagedKvCache>>> {
        let existing = self.ledger.lock().unwrap().seqs.get(&seq).cloned();
        if let Some(arc) = existing {
            return Ok(arc);
        }
        let mut cache = PagedKvCache::new(self.config.clone());
        {
            let training = self.training.lock().unwrap();
            for (layer, bytes) in training.iter().enumerate() {
                cache.dictionaries().train(layer, bytes)?;
            }
        }
        let arc = Arc::new(Mutex::new(cache));
        let mut led = self.ledger.lock().unwrap();
        // Another thread may have raced the creation; first insert wins.
        Ok(led.seqs.entry(seq).or_insert(arc).clone())
    }

    fn seq_cache(&self, seq: u64) -> Result<Arc<Mutex<PagedKvCache>>> {
        self.ledger
            .lock()
            .unwrap()
            .seqs
            .get(&seq)
            .cloned()
            .ok_or_else(|| Error::Pool(format!("unknown sequence {seq}")))
    }

    /// Append one token's K+V bytes for (sequence, layer), sealing and — if
    /// the budget demands it — evicting cold pages first so the in-memory
    /// total never exceeds the budget on account of this append.
    pub fn append_token(&self, seq: u64, layer: usize, kv_bytes: &[u8]) -> Result<()> {
        let arc = self.seq_cache_or_create(seq)?;
        let need = kv_bytes.len() as u64;
        // Reserve headroom before the bytes enter memory. We do not hold the
        // sequence lock yet, so eviction may even pick this sequence's own
        // cold pages.
        self.reserve_headroom(need, None);
        let mut cache = arc.lock().unwrap();
        let before = cache.resident_bytes();
        let sealed = cache.append_token_tracked(seq, layer, kv_bytes);
        let after = cache.resident_bytes();
        let mut led = self.ledger.lock().unwrap();
        self.settle(need, before, after);
        match sealed {
            Ok(Some(e)) => {
                led.touch((e.seq, e.layer, e.page_idx));
                Ok(())
            }
            Ok(None) => Ok(()),
            Err(err) => Err(err),
        }
    }

    /// Capture a pinned, point-in-time [`KvSnapshot`] of every layer of
    /// `seq` — **the** read entry point. Holds the sequence lock once:
    /// spilled pages are reloaded (CRC-verified), sealed pages and their
    /// dictionary tables are captured as `Arc`s, and the pool epoch is
    /// pinned. Every read on the returned handle is then lock-free and
    /// bit-exact as of this moment, no matter what eviction, spilling, or
    /// further appends do to the sequence afterwards.
    pub fn snapshot(self: &Arc<Self>, seq: u64) -> Result<KvSnapshot> {
        let arc = self.seq_cache(seq)?;
        let mut cache = arc.lock().unwrap();
        // Pin before materializing: a page this snapshot has already
        // captured can then never be reclaimed out from under it, even if
        // reloading a later layer retires it into the stash.
        let epoch = self.pin_epoch();
        let built = (|| -> Result<Vec<Option<LayerSnapshot>>> {
            let mut layers = Vec::with_capacity(self.config.n_layers);
            for layer in 0..self.config.n_layers {
                if !cache.has_list(seq, layer) {
                    layers.push(None);
                    continue;
                }
                self.reload_spilled(seq, layer, &mut cache)?;
                layers.push(Some(cache.snapshot_list(seq, layer)?));
            }
            Ok(layers)
        })();
        drop(cache);
        match built {
            Ok(layers) => {
                self.snapshots.incr();
                Ok(KvSnapshot {
                    inner: Arc::new(SnapshotInner {
                        pool: Arc::clone(self),
                        seq,
                        epoch,
                        layers,
                        reads: Arc::clone(&self.snapshot_reads),
                    }),
                })
            }
            Err(e) => {
                self.unpin_epoch(epoch);
                Err(e)
            }
        }
    }

    /// Pin the current epoch for a new snapshot: stash entries retired at
    /// any later epoch stay alive until this pin is released.
    fn pin_epoch(&self) -> u64 {
        let mut readers = self.readers.lock().unwrap();
        let e = self.epoch.load(Ordering::SeqCst);
        *readers.entry(e).or_insert(0) += 1;
        let min = *readers.keys().next().expect("just inserted");
        self.min_pinned.store(min, Ordering::SeqCst);
        drop(readers);
        self.update_epoch_lag();
        e
    }

    /// Release a snapshot's pin and reclaim whatever the stash no longer
    /// needs to keep alive.
    fn unpin_epoch(&self, epoch: u64) {
        let mut readers = self.readers.lock().unwrap();
        if let Some(n) = readers.get_mut(&epoch) {
            *n -= 1;
            if *n == 0 {
                readers.remove(&epoch);
            }
        }
        let min = readers.keys().next().copied().unwrap_or(u64::MAX);
        self.min_pinned.store(min, Ordering::SeqCst);
        drop(readers);
        self.update_epoch_lag();
        let mut led = self.ledger.lock().unwrap();
        self.reclaim_stash(&mut led);
    }

    /// `pool.epoch_lag`: how far the oldest live pin trails the retirement
    /// clock (0 with no readers) — a growing lag means some snapshot is
    /// holding retired pages, and their bytes, alive.
    fn update_epoch_lag(&self) {
        let min = self.min_pinned.load(Ordering::SeqCst);
        let lag = if min == u64::MAX {
            0
        } else {
            self.epoch.load(Ordering::SeqCst).saturating_sub(min)
        };
        self.epoch_lag.set(lag);
    }

    /// Drop every stash entry no live pin can still observe
    /// (`retired_at <= min_pinned`), crediting its bytes back to the
    /// budget. Called under the ledger lock.
    fn reclaim_stash(&self, led: &mut Ledger) {
        if led.stash.is_empty() {
            return;
        }
        let min = self.min_pinned.load(Ordering::SeqCst);
        let mut freed = 0u64;
        let mut pages = 0u64;
        led.stash.retain(|e| {
            if e.retired_at <= min {
                freed += e.bytes;
                pages += 1;
                false
            } else {
                true
            }
        });
        if pages > 0 {
            self.in_memory.sub(freed);
            self.stash_bytes.sub(freed);
            self.stash_reclaims.add(pages);
        }
    }

    /// Reload every spilled page of a (sequence, layer) list and mark the
    /// list just-used in the LRU. Caller holds the sequence lock.
    fn reload_spilled(&self, seq: u64, layer: usize, cache: &mut PagedKvCache) -> Result<()> {
        for (idx, handle) in cache.spilled_pages(seq, layer) {
            let need = handle.encoded_len as u64;
            // Make headroom (evicting if the budget demands it; the whole
            // sequence being materialized is pinned — a snapshot needs all
            // its layers resident at once) and take the reservation
            // atomically.
            self.reserve_headroom(need, Some(seq));
            // Locate the extent under a brief ledger lock; the disk read and
            // CRC check run *outside* it, so reloads of different sequences
            // overlap on the spill file.
            let located = self.ledger.lock().unwrap().spill.locate(handle.slot);
            let restored = located
                .and_then(|(off, len, crc, io)| io.read_record(off, len, crc, handle.slot))
                .and_then(|bytes| SealedPage::deserialize(&bytes))
                .and_then(|page| cache.restore_page(seq, layer, idx, page));
            if let Err(e) = restored {
                // Release the reservation; decreasing the gauge outside the
                // ledger is safe (it can only create extra headroom).
                self.in_memory.sub(need);
                return Err(e);
            }
            self.reloads.incr();
            self.ledger.lock().unwrap().touch((seq, layer, idx));
            // The disk copy stays valid (slot_of entry retained), so
            // re-evicting this page later costs no second write.
        }
        {
            // Mark every resident sealed page of this list as just-used.
            let mut led = self.ledger.lock().unwrap();
            let keys: Vec<PageKey> = led
                .tick_of
                .range((seq, layer, 0)..=(seq, layer, usize::MAX))
                .map(|(k, _)| *k)
                .collect();
            for key in keys {
                led.touch(key);
            }
        }
        Ok(())
    }

    /// Tokens stored for (sequence, layer); 0 for unknown sequences.
    pub fn token_count(&self, seq: u64, layer: usize) -> usize {
        match self.seq_cache(seq) {
            Ok(arc) => arc.lock().unwrap().token_count(seq, layer),
            Err(_) => 0,
        }
    }

    /// Seal every hot page of every live sequence (e.g. at wave end, so
    /// resident bytes reflect steady state).
    pub fn seal_all(&self) -> Result<()> {
        let arcs: Vec<Arc<Mutex<PagedKvCache>>> =
            self.ledger.lock().unwrap().seqs.values().cloned().collect();
        for arc in arcs {
            let mut cache = arc.lock().unwrap();
            let before = cache.resident_bytes();
            let events = cache.seal_all_tracked()?;
            let after = cache.resident_bytes();
            let mut led = self.ledger.lock().unwrap();
            self.settle(0, before, after);
            for e in events {
                led.touch((e.seq, e.layer, e.page_idx));
            }
        }
        Ok(())
    }

    /// Drop a sequence entirely: its memory leaves the budget and its spill
    /// slots are freed for reuse. The caller must not use `seq` afterwards.
    pub fn evict_sequence(&self, seq: u64) {
        let arc = self.ledger.lock().unwrap().seqs.remove(&seq);
        let Some(arc) = arc else { return };
        // Hold the sequence lock across the accounting so a straggler
        // holding a stale Arc cannot interleave.
        let cache = arc.lock().unwrap();
        let resident = cache.resident_bytes();
        let mut led = self.ledger.lock().unwrap();
        let keys: Vec<PageKey> = led
            .tick_of
            .range((seq, 0, 0)..=(seq, usize::MAX, usize::MAX))
            .map(|(k, _)| *k)
            .collect();
        // Sealed pages a live snapshot still pins outlive the sequence:
        // they move to the epoch stash — still physically resident, still
        // budget-charged — instead of being credited now, exactly like a
        // pinned page eviction. (Hot pages are never pinned: snapshots copy
        // them at capture.)
        let mut pinned: u64 = 0;
        for key in keys {
            led.untrack(&key);
            let Ok(page) = cache.sealed_page(key.0, key.1, key.2) else { continue };
            // Our handle + the cache's = 2; anything above is a snapshot.
            if Arc::strong_count(&page) > 2 {
                let retired_at = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
                let bytes = page.encoded_len() as u64;
                pinned += bytes;
                self.stash_bytes.add(bytes);
                led.stash.push(StashEntry { page, bytes, retired_at });
            }
        }
        self.in_memory.sub(resident.saturating_sub(pinned));
        self.update_epoch_lag();
        let slots: Vec<(PageKey, u64)> = led
            .slot_of
            .range((seq, 0, 0)..=(seq, usize::MAX, usize::MAX))
            .map(|(k, &s)| (*k, s))
            .collect();
        for (key, slot) in slots {
            led.slot_of.remove(&key);
            led.spill.free(slot);
        }
    }

    /// Live sequence ids.
    pub fn sequences(&self) -> Vec<u64> {
        self.ledger.lock().unwrap().seqs.keys().copied().collect()
    }

    /// Aggregate cache statistics across every live sequence.
    pub fn stats(&self) -> KvCacheStats {
        let arcs: Vec<Arc<Mutex<PagedKvCache>>> =
            self.ledger.lock().unwrap().seqs.values().cloned().collect();
        let mut total = KvCacheStats::default();
        for arc in arcs {
            let s = arc.lock().unwrap().stats();
            total.raw_bytes += s.raw_bytes;
            total.resident_bytes += s.resident_bytes;
            total.sealed_pages += s.sealed_pages;
            total.exp_original += s.exp_original;
            total.exp_compressed += s.exp_compressed;
            total.sm_original += s.sm_original;
            total.sm_compressed += s.sm_compressed;
            total.spilled_bytes += s.spilled_bytes;
        }
        total
    }

    /// Observability snapshot (evictions, spills, reloads, snapshots,
    /// high-water, stash/epoch state) — a typed view over
    /// [`registry`](Self::registry), which is the authoritative surface.
    pub fn counters(&self) -> PoolCounters {
        PoolCounters::from_snapshot(&self.registry.snapshot(), self.budget)
    }

    /// Apply the difference between the reserved headroom and what an
    /// operation actually added. Called under the ledger lock so budget
    /// checks and gauge updates are atomic with respect to each other.
    fn settle(&self, reserved: u64, before: u64, after: u64) {
        let delta = after as i64 - before as i64;
        let adjust = reserved as i64 - delta;
        match adjust.cmp(&0) {
            std::cmp::Ordering::Greater => {
                self.in_memory.sub(adjust as u64);
            }
            std::cmp::Ordering::Less => {
                self.in_memory.add((-adjust) as u64);
            }
            std::cmp::Ordering::Equal => {}
        }
    }

    /// Reserve `need` bytes of in-memory headroom, evicting cold sealed
    /// pages (LRU-first) until the reservation fits under the budget or
    /// nothing evictable remains. Bytes freed by this call are credited to
    /// this call and settled against the reservation in one locked step, so
    /// concurrent reservations cannot steal the headroom it frees.
    ///
    /// `exclude` pins every page of the sequence a snapshot is
    /// materializing (the snapshot needs the whole sequence resident, and
    /// its own lock is already held — a `try_lock` on it would self-skip
    /// anyway). Victims whose sequence lock is busy are skipped (and
    /// re-marked hot), never waited on — see the module docs on lock order.
    fn reserve_headroom(&self, need: u64, exclude: Option<u64>) {
        let Some(budget) = self.budget else {
            self.in_memory.add(need);
            return;
        };
        let mut credit: u64 = 0;
        // Each skipped victim is re-inserted hot, so bound the scan.
        let mut attempts: Option<usize> = None;
        loop {
            let mut led = self.ledger.lock().unwrap();
            // Stash entries whose pins have since released are free bytes:
            // harvest them before (and instead of) evicting more pages.
            self.reclaim_stash(&mut led);
            let left = attempts.get_or_insert_with(|| led.lru.len() + 8);
            let fits = self.in_memory.get() + need <= budget.saturating_add(credit);
            if fits || *left == 0 {
                // Settle under the ledger: return the credited bytes and
                // take the reservation atomically. Exceeding the budget here
                // means there was genuinely nothing left to evict.
                self.in_memory.sub(credit);
                self.in_memory.add(need);
                return;
            }
            *left -= 1;
            let Some((&tick, &key)) = led.lru.iter().next() else {
                self.in_memory.sub(credit);
                self.in_memory.add(need);
                return;
            };
            led.lru.remove(&tick);
            led.tick_of.remove(&key);
            if Some(key.0) == exclude {
                led.touch(key); // pinned by the in-flight snapshot build
                continue;
            }
            let Some(arc) = led.seqs.get(&key.0).cloned() else { continue };
            match arc.try_lock() {
                Ok(mut guard) => {
                    credit += self.evict_victim(led, &mut guard, key);
                }
                Err(_) => {
                    // Busy victim: skip, re-mark hot, try a colder one.
                    led.touch(key);
                }
            }
        }
    }

    /// Move one sealed page of `cache` (whose sequence lock the caller
    /// holds) to the spill file, performing the disk write *outside* the
    /// ledger. Returns the encoded bytes freed from memory — 0 if the page
    /// was not actually sealed+resident, the spill write failed, **or** a
    /// live snapshot still pins the page: then the bytes move to the epoch
    /// stash instead of being freed, and are credited only at reclaim.
    fn evict_victim(
        &self,
        led: MutexGuard<'_, Ledger>,
        cache: &mut PagedKvCache,
        key: PageKey,
    ) -> u64 {
        let (seq, layer, idx) = key;
        let existing = led.slot_of.get(&key).copied();
        // Everything byte-sized — page clone, record serialization, CRC,
        // and the positioned write — runs OFF the ledger, under only the
        // victim's sequence lock (held by the caller): evictions and
        // reloads of other sequences proceed concurrently. The sequence
        // lock also keeps `slot_of` for this key stable (readers and
        // `evict_sequence` both need it before touching this page).
        drop(led);
        let Ok(page) = cache.sealed_page(seq, layer, idx) else {
            // State changed under us (should not happen); drop tracking.
            return 0;
        };
        let encoded_len = page.encoded_len();
        let raw_len = page.raw_len();
        let slot = match existing {
            // Already on disk from an earlier round trip: no I/O at all.
            Some(slot) => slot,
            None => {
                let record = page.serialize();
                let crc = crate::util::crc32::crc32(&record);
                let reserved = {
                    let mut led = self.ledger.lock().unwrap();
                    match led.spill.reserve(record.len(), crc) {
                        Ok(r) => r,
                        Err(_) => {
                            led.touch(key);
                            return 0;
                        }
                    }
                };
                let (slot, offset, io) = reserved;
                let wrote = io.write_at(&record, offset);
                let mut led = self.ledger.lock().unwrap();
                if wrote.is_err() {
                    // Hand the extent back; the page stays resident+tracked.
                    led.spill.free(slot);
                    led.touch(key);
                    return 0;
                }
                led.slot_of.insert(key, slot);
                self.spills.incr();
                drop(led);
                slot
            }
        };
        let handle = SpilledHandle { slot, encoded_len, raw_len };
        // Drop our own Arc before the pin check: after `mark_spilled` the
        // only remaining strong counts are live snapshots' (new snapshots of
        // this sequence are excluded by the sequence lock we hold).
        drop(page);
        let Ok(displaced) = cache.mark_spilled(seq, layer, idx, handle) else {
            return 0;
        };
        self.evictions.incr();
        if Arc::strong_count(&displaced) > 1 {
            // A live snapshot still reads these bytes: retire into the stash
            // at a fresh epoch and credit nothing — the budget keeps
            // charging them until the last pre-retirement pin releases.
            let retired_at = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
            let bytes = encoded_len as u64;
            self.stash_bytes.add(bytes);
            self.ledger
                .lock()
                .unwrap()
                .stash
                .push(StashEntry { page: displaced, bytes, retired_at });
            self.update_epoch_lag();
            0
        } else {
            encoded_len as u64
        }
    }
}

/// Shared state of one snapshot; clones of a [`KvSnapshot`] share it, and
/// the epoch pin is released exactly once, when the last clone drops.
#[derive(Debug)]
struct SnapshotInner {
    pool: Arc<SharedKvPool>,
    seq: u64,
    epoch: u64,
    /// One entry per layer; `None` where the sequence has no data.
    layers: Vec<Option<LayerSnapshot>>,
    reads: Arc<Counter>,
}

impl Drop for SnapshotInner {
    fn drop(&mut self) {
        self.pool.unpin_epoch(self.epoch);
    }
}

/// A pinned, point-in-time, lock-free read handle over one sequence of a
/// [`SharedKvPool`] — the result of [`SharedKvPool::snapshot`].
///
/// Cheap to `Clone` (an `Arc` bump) and `Send`, so the decode fan-out hands
/// one clone to each worker. Reads ([`read_into`](Self::read_into),
/// [`read`](Self::read)) entropy-decode straight from the captured
/// immutable pages without taking any pool or sequence lock, and stay
/// bit-exact no matter what eviction, spilling, or further appends happen
/// after the snapshot was taken. Dropping the last clone releases the epoch
/// pin, letting the pool reclaim any pages the evictor stashed meanwhile.
#[derive(Clone, Debug)]
pub struct KvSnapshot {
    inner: Arc<SnapshotInner>,
}

impl KvSnapshot {
    /// The sequence this snapshot captured.
    pub fn seq(&self) -> u64 {
        self.inner.seq
    }

    /// The pool epoch pinned at creation (diagnostics; compare with
    /// `pool.epoch_lag`).
    pub fn epoch(&self) -> u64 {
        self.inner.epoch
    }

    fn layer(&self, layer: usize) -> Result<&LayerSnapshot> {
        self.inner
            .layers
            .get(layer)
            .and_then(|l| l.as_ref())
            .ok_or_else(|| {
                Error::Pool(format!("no cache for seq {} layer {layer}", self.inner.seq))
            })
    }

    /// Logical byte length of the captured (sequence, layer) stream — the
    /// buffer size [`read_into`](Self::read_into) requires.
    pub fn len(&self, layer: usize) -> Result<usize> {
        Ok(self.layer(layer)?.len())
    }

    /// Lock-free, bit-exact read of the captured layer stream into `out`
    /// (exactly [`len`](Self::len) bytes). Returns the bytes written.
    pub fn read_into(&self, layer: usize, out: &mut [u8]) -> Result<usize> {
        self.reads_incr();
        self.layer(layer)?.read_into(out)
    }

    /// Allocating variant of [`read_into`](Self::read_into).
    pub fn read(&self, layer: usize) -> Result<Vec<u8>> {
        self.reads_incr();
        self.layer(layer)?.read()
    }

    fn reads_incr(&self) {
        self.inner.reads.incr();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::conv::quantize_slice;
    use crate::formats::FloatFormat;
    use crate::synthetic;
    use std::collections::BTreeMap;

    fn bf16_config() -> KvCacheConfig {
        let mut c = KvCacheConfig::new(2, 64 * 2, FloatFormat::Bf16);
        c.page_tokens = 8;
        c
    }

    fn token_bytes(config: &KvCacheConfig, seed: u64) -> Vec<u8> {
        synthetic::kv_token_bytes(config, seed)
    }

    #[test]
    fn budget_forces_spill_reads_bit_exact() {
        let config = bf16_config();
        // Hot working set: 3 seqs x 2 layers x 8-token pages x 256 B/token
        // = 12 KiB. A snapshot materializes *every* layer of its sequence
        // at once (~64 KiB raw for one sequence here), so 96 KiB leaves
        // room for one fully resident sequence while staying far below the
        // ~240 KiB raw footprint.
        let budget = 96 * 1024;
        let pool =
            SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
        let mut shadows: BTreeMap<(u64, usize), Vec<u8>> = BTreeMap::new();
        for t in 0..160u64 {
            for seq in 1..=3u64 {
                for layer in 0..2usize {
                    let kv = token_bytes(&config, t * 131 + seq * 7 + layer as u64);
                    pool.append_token(seq, layer, &kv).unwrap();
                    shadows.entry((seq, layer)).or_default().extend_from_slice(&kv);
                }
            }
            if t % 40 == 39 {
                for seq in 1..=3u64 {
                    let snap = pool.snapshot(seq).unwrap();
                    for layer in 0..2usize {
                        assert_eq!(
                            &snap.read(layer).unwrap(),
                            &shadows[&(seq, layer)],
                            "t={t} seq={seq} layer={layer}"
                        );
                    }
                }
            }
        }
        let c = pool.counters();
        assert!(c.spills > 0, "budget never forced a spill: {c}");
        assert!(c.reloads > 0, "snapshots never reloaded a spilled page: {c}");
        assert!(c.evictions >= c.spills);
        assert!(c.snapshots > 0 && c.snapshot_reads > 0, "read path untracked: {c}");
        assert!(c.within_budget(), "budget violated: {c}");
        assert!(c.high_water_bytes <= budget);
        let stats = pool.stats();
        assert!(stats.raw_bytes > budget, "test must oversubscribe the budget");
        assert_eq!(pool.sequences(), vec![1, 2, 3]);
        assert_eq!(pool.token_count(1, 0), 160);
        // The zero-copy path reloads spilled pages just the same.
        for (&(seq, layer), shadow) in &shadows {
            let snap = pool.snapshot(seq).unwrap();
            let mut buf = vec![0u8; snap.len(layer).unwrap()];
            snap.read_into(layer, &mut buf).unwrap();
            assert_eq!(&buf, shadow, "read_into seq {seq} layer {layer}");
        }
        assert!(pool.counters().within_budget(), "{}", pool.counters());
    }

    #[test]
    fn unbounded_pool_never_evicts() {
        let config = bf16_config();
        let pool = SharedKvPool::new(PoolConfig::new(config.clone())).unwrap();
        let mut shadow = Vec::new();
        for t in 0..64u64 {
            let kv = token_bytes(&config, 900 + t);
            pool.append_token(5, 1, &kv).unwrap();
            shadow.extend_from_slice(&kv);
        }
        let snap = pool.snapshot(5).unwrap();
        assert_eq!(snap.seq(), 5);
        assert_eq!(snap.read(1).unwrap(), shadow);
        // Zero-copy read path agrees bit for bit and validates its buffer.
        let mut buf = vec![0u8; snap.len(1).unwrap()];
        snap.read_into(1, &mut buf).unwrap();
        assert_eq!(buf, shadow);
        let mut short = vec![0u8; buf.len() - 1];
        assert!(snap.read_into(1, &mut short).is_err());
        // Layer 0 never saw data: the handle says so instead of panicking.
        assert!(snap.read(0).is_err());
        drop(snap);
        let c = pool.counters();
        assert_eq!(c.evictions, 0);
        assert_eq!(c.spills, 0);
        assert_eq!(c.reloads, 0);
        assert_eq!(c.snapshots, 1);
        assert_eq!(c.epoch_lag, 0);
        assert!(c.within_budget());
        assert_eq!(c.in_memory_bytes, pool.stats().resident_bytes);
    }

    #[test]
    fn scoped_registry_matches_counters_facade() {
        use crate::obs::MetricValue;
        let config = bf16_config();
        let budget = 24 * 1024;
        let pool =
            SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
        for t in 0..80u64 {
            for layer in 0..2usize {
                pool.append_token(9, layer, &token_bytes(&config, 400 + t * 2 + layer as u64))
                    .unwrap();
            }
        }
        // Exercise the read path so its metrics are non-trivially non-zero.
        let handle = pool.snapshot(9).unwrap();
        handle.read(0).unwrap();
        handle.read(1).unwrap();
        drop(handle);
        let c = pool.counters();
        assert_eq!(c.snapshots, 1);
        assert_eq!(c.snapshot_reads, 2);
        let snap = pool.registry().snapshot();
        // Exact equality is safe here: the registry is scoped per pool, so
        // no other test's traffic can leak into it — and `counters()` is by
        // construction a view over this same registry.
        match snap.get("pool.evictions_total") {
            Some(&MetricValue::Counter(n)) => assert_eq!(n, c.evictions),
            other => panic!("unexpected {other:?}"),
        }
        match snap.get("pool.spills_total") {
            Some(&MetricValue::Counter(n)) => assert_eq!(n, c.spills),
            other => panic!("unexpected {other:?}"),
        }
        match snap.get("pool.reloads_total") {
            Some(&MetricValue::Counter(n)) => assert_eq!(n, c.reloads),
            other => panic!("unexpected {other:?}"),
        }
        match snap.get("pool.snapshots_total") {
            Some(&MetricValue::Counter(n)) => assert_eq!(n, c.snapshots),
            other => panic!("unexpected {other:?}"),
        }
        match snap.get("pool.snapshot_reads_total") {
            Some(&MetricValue::Counter(n)) => assert_eq!(n, c.snapshot_reads),
            other => panic!("unexpected {other:?}"),
        }
        match snap.get("pool.in_memory_bytes") {
            Some(&MetricValue::Gauge { value, high_water }) => {
                assert_eq!(value, c.in_memory_bytes);
                assert_eq!(high_water, c.high_water_bytes);
            }
            other => panic!("unexpected {other:?}"),
        }
        match snap.get("pool.stash_bytes") {
            Some(&MetricValue::Gauge { value, .. }) => assert_eq!(value, c.stash_bytes),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn evict_sequence_frees_budget_and_slots() {
        let config = bf16_config();
        let budget = 24 * 1024;
        let pool =
            SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
        for seq in 1..=2u64 {
            for t in 0..80u64 {
                for layer in 0..2usize {
                    let kv = token_bytes(&config, seq * 1000 + t * 3 + layer as u64);
                    pool.append_token(seq, layer, &kv).unwrap();
                }
            }
        }
        assert!(pool.counters().spills > 0);
        let before = pool.counters().in_memory_bytes;
        pool.evict_sequence(1);
        let after = pool.counters();
        assert!(after.in_memory_bytes < before);
        assert_eq!(pool.sequences(), vec![2]);
        assert!(pool.snapshot(1).is_err());
        assert_eq!(pool.token_count(1, 0), 0);
        // Seq 2 still reads back fine after its neighbour vanished.
        assert_eq!(
            pool.snapshot(2).unwrap().read(0).unwrap().len(),
            80 * 2 * config.bytes_per_token
        );
    }

    #[test]
    fn seal_all_registers_pages_for_eviction() {
        let config = bf16_config();
        let pool = SharedKvPool::new(
            PoolConfig::new(config.clone()).with_budget(512 * 1024),
        )
        .unwrap();
        // 5 tokens: less than one page, so only seal_all can seal it.
        for t in 0..5u64 {
            pool.append_token(9, 0, &token_bytes(&config, t)).unwrap();
        }
        assert_eq!(pool.stats().sealed_pages, 0);
        pool.seal_all().unwrap();
        let stats = pool.stats();
        assert_eq!(stats.sealed_pages, 1);
        assert!(stats.resident_bytes <= stats.raw_bytes);
        assert_eq!(pool.counters().in_memory_bytes, stats.resident_bytes);
    }

    #[test]
    fn dictionary_training_applies_to_new_sequences() {
        let config = bf16_config();
        let pool = SharedKvPool::new(PoolConfig::new(config.clone())).unwrap();
        let vals = synthetic::kv_cache_f32(512, 128, 21);
        let bytes = quantize_slice(&vals, config.format).unwrap();
        let set = crate::formats::split_streams(config.format, &bytes).unwrap();
        let exp = set.exponent().unwrap().bytes.clone();
        pool.train_dictionaries(&[exp.clone(), exp]).unwrap();
        let mut shadow = Vec::new();
        for t in 0..32u64 {
            let kv = token_bytes(&config, 700 + t);
            pool.append_token(1, 0, &kv).unwrap();
            shadow.extend_from_slice(&kv);
        }
        pool.seal_all().unwrap();
        assert_eq!(pool.snapshot(1).unwrap().read(0).unwrap(), shadow);
        let stats = pool.stats();
        assert!(stats.exp_ratio() < 0.7, "trained dict exp ratio {}", stats.exp_ratio());
    }

    #[test]
    fn rans_backed_pool_spills_and_reloads_bit_exact() {
        // Pin the rANS backend end-to-end through spill round trips: the
        // new stream frames must survive serialize → pwrite → pread →
        // deserialize → decode unchanged.
        let mut config = bf16_config();
        config.codec = crate::codec::Codec::Rans;
        // Snapshots materialize the whole sequence (one layer here,
        // ~30 KiB raw), so the budget leaves room for that plus hot pages.
        let budget = 40 * 1024;
        let pool =
            SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
        let mut shadows: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        for t in 0..120u64 {
            for seq in 1..=2u64 {
                let kv = token_bytes(&config, t * 17 + seq);
                pool.append_token(seq, 0, &kv).unwrap();
                shadows.entry(seq).or_default().extend_from_slice(&kv);
            }
        }
        let c = pool.counters();
        assert!(c.spills > 0, "scenario must spill: {c}");
        for (&seq, shadow) in &shadows {
            assert_eq!(&pool.snapshot(seq).unwrap().read(0).unwrap(), shadow, "seq {seq}");
        }
        assert!(pool.counters().reloads > 0);
        assert!(pool.counters().within_budget(), "{}", pool.counters());
    }

    #[test]
    fn concurrent_reloads_overlap_off_the_ledger() {
        // Two reader threads reload different sequences at the same time.
        // Before spill I/O moved off the ledger mutex, their disk reads
        // serialized on it; now the spill file's read-concurrency
        // high-water mark must reach >= 2.
        let mut config = KvCacheConfig::new(1, 2048, FloatFormat::Bf16);
        config.page_tokens = 16; // 16 tokens x 4 KiB = 64 KiB raw pages
        let rounds = 8u64;
        let tokens = 64u64; // 4 pages per sequence
        let seq_raw = tokens * 2 * config.bytes_per_token as u64; // 256 KiB
        // Holds two full sequences plus slack; appending later rounds
        // pushes earlier rounds' pages to disk, so every round's two reads
        // are reload-heavy.
        let budget = seq_raw * 5 / 2;
        let pool =
            SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
        let mut shadows: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        for round in 0..rounds {
            for lane in 0..2u64 {
                let seq = round * 2 + lane;
                for t in 0..tokens {
                    let kv = token_bytes(&config, seq * 100_003 + t);
                    pool.append_token(seq, 0, &kv).unwrap();
                    shadows.entry(seq).or_default().extend_from_slice(&kv);
                }
            }
        }
        pool.seal_all().unwrap();
        assert!(pool.counters().spills > 0, "appends never spilled: {}", pool.counters());
        for round in 0..rounds {
            // Both readers start from a barrier so their multi-page reload
            // loops (hundreds of microseconds of pread + CRC each) run over
            // the same wall-clock window instead of at the scheduler's whim.
            let barrier = std::sync::Barrier::new(2);
            std::thread::scope(|scope| {
                for lane in 0..2u64 {
                    let seq = round * 2 + lane;
                    let pool = &pool;
                    let shadow = &shadows[&seq];
                    let barrier = &barrier;
                    scope.spawn(move || {
                        barrier.wait();
                        let snap = pool.snapshot(seq).unwrap();
                        assert_eq!(&snap.read(0).unwrap(), shadow, "seq {seq}");
                    });
                }
            });
            if pool.counters().spill_read_concurrency >= 2 {
                break;
            }
        }
        let c = pool.counters();
        assert!(c.reloads > 0, "no reloads happened: {c}");
        assert!(c.within_budget(), "budget violated: {c}");
        // The overlap itself needs two hardware threads to be observable;
        // on a single-core runner the bit-exactness + reload assertions
        // above still validate the protocol, so only assert the
        // concurrency high-water when the machine can physically exhibit it.
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        assert!(
            cores < 2 || c.spill_read_concurrency >= 2,
            "spill reads never overlapped across {rounds} rounds on {cores} cores: {c}"
        );
    }

    #[test]
    fn snapshot_survives_eviction_and_stash_reclaims() {
        let config = bf16_config();
        // Small enough that flooding a second sequence must evict the
        // first one's pages out from under its live snapshot.
        let budget = 32 * 1024;
        let pool =
            SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
        let mut shadow = Vec::new();
        for t in 0..64u64 {
            let kv = token_bytes(&config, 3_000 + t);
            pool.append_token(1, 0, &kv).unwrap();
            shadow.extend_from_slice(&kv);
        }
        let snap = pool.snapshot(1).unwrap();
        assert_eq!(snap.read(0).unwrap(), shadow);
        // Flood: 240 tokens x 256 B = 60 KiB raw on a 32 KiB budget. The
        // evictor must retire seq 1's pages, but the snapshot pins them —
        // into the stash they go, uncredited.
        for t in 0..240u64 {
            pool.append_token(2, 0, &token_bytes(&config, 9_000 + t)).unwrap();
        }
        let mid = pool.counters();
        assert!(mid.evictions > 0, "flood never evicted: {mid}");
        assert!(mid.stash_bytes > 0, "pinned eviction never stashed: {mid}");
        assert!(mid.epoch_lag > 0, "pin should trail the retirement clock: {mid}");
        // The snapshot still reads the retired pages bit-exactly, lock-free.
        assert_eq!(snap.read(0).unwrap(), shadow);
        // A clone shares the pin: dropping the original frees nothing yet.
        let clone = snap.clone();
        drop(snap);
        assert!(pool.counters().stash_bytes > 0, "{}", pool.counters());
        assert_eq!(clone.read(0).unwrap(), shadow);
        // Last handle gone -> pin released -> stash reclaimed and credited.
        drop(clone);
        let end = pool.counters();
        assert_eq!(end.stash_bytes, 0, "stash not reclaimed: {end}");
        assert!(end.stash_reclaims > 0, "{end}");
        assert_eq!(end.epoch_lag, 0, "{end}");
        assert!(end.within_budget(), "budget violated: {end}");
        // The evicted pages went to disk as usual: a fresh snapshot reloads
        // them and still agrees with the shadow.
        assert_eq!(pool.snapshot(1).unwrap().read(0).unwrap(), shadow);
        assert!(pool.counters().within_budget(), "{}", pool.counters());
    }
}
