//! The disk spill file backing cold evicted pages.
//!
//! Layout: a single flat file of variable-length page records, each written
//! at a slot offset chosen by a smallest-fit scan over freed extents (falling
//! back to appending at the end). Every record's CRC-32 is kept **in memory**
//! alongside its extent and verified on read, so a damaged spill file is
//! detected before corrupt bytes can reach an attention computation — the
//! same integrity discipline the `zlp` container applies per chunk.
//!
//! Slots are identities, extents are storage: a slot id never changes while
//! its page lives in the file, even if compaction were to move extents later.
//!
//! The structure is split along the pool's locking boundary:
//!
//! * [`SpillFile`] — the allocator (extent maps, slot directory). Lives
//!   under the pool's ledger mutex; every operation is in-memory and cheap.
//! * [`SpillIo`] — the shared file handle doing **positioned** reads and
//!   writes (`pread`/`pwrite`-style, no seek state). Handed out as an `Arc`
//!   by [`SpillFile::reserve`] / [`SpillFile::locate`] so the actual disk
//!   I/O runs *outside* the ledger mutex: reloads and evictions of
//!   different sequences overlap instead of serializing on the lock.

use crate::error::{Error, Result};
use crate::obs::{Counter, Gauge, Registry};
use crate::util::crc32::crc32;
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Extent + integrity metadata for one live slot.
#[derive(Clone, Copy, Debug)]
struct Slot {
    offset: u64,
    len: u64,
    crc: u32,
}

/// The shared, position-addressed spill-file handle.
///
/// All methods take `&self`: positioned I/O has no cursor, so any number of
/// threads may read and write disjoint extents concurrently. Traffic
/// figures live in the owning pool's scoped registry
/// (`pool.spill_bytes_written_total`, `pool.spill_bytes_read_total`,
/// `pool.spill_read_concurrency`) so there is one metrics surface.
#[derive(Debug)]
pub struct SpillIo {
    file: File,
    path: PathBuf,
    remove_on_drop: bool,
    bytes_written: Arc<Counter>,
    bytes_read: Arc<Counter>,
    /// Concurrent `read_record` calls in flight; the high-water mark proves
    /// (in tests) that reloads genuinely overlap off the ledger mutex.
    concurrent_reads: Arc<Gauge>,
    /// Serializes seek+read/write on targets without positioned I/O.
    #[cfg(not(unix))]
    cursor: std::sync::Mutex<()>,
}

impl SpillIo {
    /// Write `buf` at `offset`, atomically from the caller's perspective.
    pub fn write_at(&self, buf: &[u8], offset: u64) -> Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.write_all_at(buf, offset)?;
        }
        #[cfg(not(unix))]
        {
            use std::io::{Seek, SeekFrom, Write};
            let _guard = self.cursor.lock().unwrap();
            let mut f = &self.file;
            f.seek(SeekFrom::Start(offset))?;
            f.write_all(buf)?;
        }
        self.bytes_written.add(buf.len() as u64);
        Ok(())
    }

    /// Read `len` bytes at `offset` and verify them against `crc`;
    /// `slot` only labels the checksum error.
    pub fn read_record(&self, offset: u64, len: u64, crc: u32, slot: u64) -> Result<Vec<u8>> {
        self.concurrent_reads.add(1);
        let result = self.read_at(offset, len).and_then(|buf| {
            let actual = crc32(&buf);
            if actual != crc {
                return Err(Error::ChecksumMismatch {
                    chunk: slot as usize,
                    expected: crc,
                    actual,
                });
            }
            Ok(buf)
        });
        self.concurrent_reads.sub(1);
        let buf = result?;
        self.bytes_read.add(len);
        Ok(buf)
    }

    fn read_at(&self, offset: u64, len: u64) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; len as usize];
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_exact_at(&mut buf, offset)?;
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let _guard = self.cursor.lock().unwrap();
            let mut f = &self.file;
            f.seek(SeekFrom::Start(offset))?;
            f.read_exact(&mut buf)?;
        }
        Ok(buf)
    }

    /// Where the file lives on disk.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// All-time maximum number of overlapping [`read_record`][Self::read_record]
    /// calls — ≥ 2 demonstrates reloads running concurrently.
    pub fn max_concurrent_reads(&self) -> u64 {
        self.concurrent_reads.high_water()
    }
}

impl Drop for SpillIo {
    fn drop(&mut self) {
        if self.remove_on_drop {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// A spill file holding serialized [`crate::kvcache::SealedPage`] records:
/// the extent allocator plus a shared [`SpillIo`] handle.
#[derive(Debug)]
pub struct SpillFile {
    io: Arc<SpillIo>,
    /// Bytes currently parked in live slots (`pool.spilled_bytes` in the
    /// owning registry), maintained at reserve/free.
    live: Arc<Gauge>,
    /// File length high-water mark (append offset).
    end: u64,
    slots: BTreeMap<u64, Slot>,
    /// Free extents keyed `(len, offset)` so `range((need, 0)..)` finds the
    /// smallest extent that fits.
    free_extents: BTreeMap<(u64, u64), ()>,
    /// The same extents keyed by offset, for coalescing with neighbours.
    free_by_offset: BTreeMap<u64, u64>,
    next_slot: u64,
}

impl SpillFile {
    /// Create (or truncate) a spill file at `path`, reporting its traffic
    /// into `registry` (the owning pool's scoped registry).
    pub fn create(path: &Path, registry: &Registry) -> Result<Self> {
        Self::create_inner(path, false, registry)
    }

    /// Create a uniquely named spill file in the OS temp directory, removed
    /// when the last handle drops.
    pub fn temp(registry: &Registry) -> Result<Self> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("zipnn-lp-pool-{}-{}.spill", std::process::id(), n));
        Self::create_inner(&path, true, registry)
    }

    fn create_inner(path: &Path, remove_on_drop: bool, registry: &Registry) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(SpillFile {
            io: Arc::new(SpillIo {
                file,
                path: path.to_path_buf(),
                remove_on_drop,
                bytes_written: registry.counter("pool.spill_bytes_written_total"),
                bytes_read: registry.counter("pool.spill_bytes_read_total"),
                concurrent_reads: registry.gauge("pool.spill_read_concurrency"),
                #[cfg(not(unix))]
                cursor: std::sync::Mutex::new(()),
            }),
            live: registry.gauge("pool.spilled_bytes"),
            end: 0,
            slots: BTreeMap::new(),
            free_extents: BTreeMap::new(),
            free_by_offset: BTreeMap::new(),
            next_slot: 0,
        })
    }

    /// Reserve an extent + slot for a `len`-byte record with checksum `crc`
    /// (computed by the caller, off this allocator's lock) without writing
    /// it. Returns the slot id, the byte offset, and the shared I/O handle
    /// so the caller can perform the write *after* releasing whatever lock
    /// guards this allocator. A failed write must be undone with
    /// [`free`][Self::free].
    pub fn reserve(&mut self, len: usize, crc: u32) -> Result<(u64, u64, Arc<SpillIo>)> {
        let need = len as u64;
        if need == 0 {
            return Err(Error::Pool("refusing to spill an empty page record".into()));
        }
        let reuse = self
            .free_extents
            .range((need, 0)..)
            .next()
            .map(|(&extent, _)| extent);
        let offset = match reuse {
            Some((len, off)) => {
                self.remove_free(off, len);
                if len > need {
                    // Return the unused tail of the extent.
                    self.insert_free(off + need, len - need);
                }
                off
            }
            None => {
                let off = self.end;
                self.end += need;
                off
            }
        };
        let slot = self.next_slot;
        self.next_slot += 1;
        self.slots.insert(slot, Slot { offset, len: need, crc });
        self.live.add(need);
        Ok((slot, offset, self.io.clone()))
    }

    /// Look up a slot's extent and checksum, plus the shared I/O handle for
    /// reading it outside the allocator's lock.
    pub fn locate(&self, slot: u64) -> Result<(u64, u64, u32, Arc<SpillIo>)> {
        let s = self
            .slots
            .get(&slot)
            .ok_or_else(|| Error::Pool(format!("unknown spill slot {slot}")))?;
        Ok((s.offset, s.len, s.crc, self.io.clone()))
    }

    /// Write one page record synchronously, returning its slot id.
    /// Convenience composition of [`reserve`][Self::reserve] + I/O used by
    /// tests and single-threaded callers.
    pub fn write(&mut self, record: &[u8]) -> Result<u64> {
        let (slot, offset, io) = self.reserve(record.len(), crc32(record))?;
        if let Err(e) = io.write_at(record, offset) {
            // Hand the extent back (append case: end shrinks again) so a
            // failing disk cannot leak spill-file space on every retry.
            self.free(slot);
            return Err(e);
        }
        Ok(slot)
    }

    /// Read back a slot's record synchronously, verifying its CRC-32.
    pub fn read(&self, slot: u64) -> Result<Vec<u8>> {
        let (offset, len, crc, io) = self.locate(slot)?;
        io.read_record(offset, len, crc, slot)
    }

    /// Release a slot, returning its extent to the free list (coalesced
    /// with free neighbours so long-lived files do not fragment without
    /// bound). Unknown slots are ignored (freeing is idempotent).
    pub fn free(&mut self, slot: u64) {
        if let Some(s) = self.slots.remove(&slot) {
            self.live.sub(s.len);
            self.insert_free(s.offset, s.len);
        }
    }

    fn remove_free(&mut self, offset: u64, len: u64) {
        self.free_by_offset.remove(&offset);
        self.free_extents.remove(&(len, offset));
    }

    /// Insert a free extent, merging it with adjacent free extents; an
    /// extent that reaches the end of the file shrinks the append offset
    /// instead of being kept.
    fn insert_free(&mut self, offset: u64, len: u64) {
        let mut offset = offset;
        let mut len = len;
        if let Some((&succ_off, &succ_len)) = self.free_by_offset.range(offset..).next() {
            if offset + len == succ_off {
                self.remove_free(succ_off, succ_len);
                len += succ_len;
            }
        }
        if let Some((&pred_off, &pred_len)) = self.free_by_offset.range(..offset).next_back() {
            if pred_off + pred_len == offset {
                self.remove_free(pred_off, pred_len);
                offset = pred_off;
                len += pred_len;
            }
        }
        if offset + len == self.end {
            self.end = offset;
            return;
        }
        self.free_by_offset.insert(offset, len);
        self.free_extents.insert((len, offset), ());
    }

    /// The shared I/O handle (observability: concurrency high-water).
    pub fn io(&self) -> &Arc<SpillIo> {
        &self.io
    }

    /// Where the file lives on disk.
    pub fn path(&self) -> &Path {
        self.io.path()
    }

    /// Number of live (occupied) slots.
    pub fn live_slots(&self) -> usize {
        self.slots.len()
    }

    /// Bytes currently parked in live slots.
    pub fn live_bytes(&self) -> u64 {
        self.slots.values().map(|s| s.len).sum()
    }

    /// Total record bytes ever written (spill write traffic).
    pub fn bytes_written(&self) -> u64 {
        self.io.bytes_written.get()
    }

    /// Total record bytes ever read back (reload traffic).
    pub fn bytes_read(&self) -> u64 {
        self.io.bytes_read.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip_with_crc() {
        let mut f = SpillFile::temp(&Registry::new()).unwrap();
        let a: Vec<u8> = (0..300u32).map(|i| (i * 7) as u8).collect();
        let b: Vec<u8> = (0..100u32).map(|i| (i * 13 + 1) as u8).collect();
        let sa = f.write(&a).unwrap();
        let sb = f.write(&b).unwrap();
        assert_ne!(sa, sb);
        assert_eq!(f.read(sa).unwrap(), a);
        assert_eq!(f.read(sb).unwrap(), b);
        // Reads are repeatable.
        assert_eq!(f.read(sa).unwrap(), a);
        assert_eq!(f.live_slots(), 2);
        assert_eq!(f.live_bytes(), 400);
        assert_eq!(f.bytes_written(), 400);
        assert!(f.bytes_read() >= 700);
    }

    #[test]
    fn freed_extents_reused_and_coalesced() {
        let mut f = SpillFile::temp(&Registry::new()).unwrap();
        let a = f.write(&[1u8; 300]).unwrap(); // 0..300
        let b = f.write(&[2u8; 300]).unwrap(); // 300..600
        let c = f.write(&[3u8; 300]).unwrap(); // 600..900
        let d = f.write(&[4u8; 100]).unwrap(); // 900..1000 pins the end
        assert_eq!(f.end, 1000);
        // Free a and c (disjoint), then b: all three must coalesce into one
        // 0..900 extent.
        f.free(a);
        f.free(c);
        f.free(b);
        // A 700-byte record fits only in the coalesced hole; without
        // coalescing it would append at 1000 and grow the file.
        let e = f.write(&[5u8; 700]).unwrap(); // 0..700; tail 700..900 free
        assert_eq!(f.end, 1000, "file grew despite coalesced free space");
        assert_eq!(f.read(e).unwrap(), vec![5u8; 700]);
        assert_eq!(f.read(d).unwrap(), vec![4u8; 100]);
        // Freeing the trailing records shrinks the append offset back to 0:
        // d merges with the free 700..900 tail and reaches the end
        // (1000 -> 700), then e's 0..700 extent does the same (-> 0).
        f.free(d);
        assert_eq!(f.end, 700);
        f.free(e);
        assert_eq!(f.end, 0);
        assert_eq!(f.live_slots(), 0);
        // Double-free is a no-op.
        f.free(d);
        assert_eq!(f.live_slots(), 0);
    }

    #[test]
    fn reserve_then_positioned_write_out_of_band() {
        // The pool's eviction path: reserve under a lock, write without it.
        let mut f = SpillFile::temp(&Registry::new()).unwrap();
        let rec: Vec<u8> = (0..500u32).map(|i| (i * 3) as u8).collect();
        let (slot, offset, io) = f.reserve(rec.len(), crc32(&rec)).unwrap();
        // Nothing written yet, but the slot is addressable.
        io.write_at(&rec, offset).unwrap();
        assert_eq!(f.read(slot).unwrap(), rec);
        // locate + read_record is the decomposed read path.
        let (off2, len2, crc2, io2) = f.locate(slot).unwrap();
        assert_eq!((off2, len2), (offset, rec.len() as u64));
        assert_eq!(io2.read_record(off2, len2, crc2, slot).unwrap(), rec);
        // A reservation abandoned via free() returns its extent.
        let (slot2, _, _) = f.reserve(100, crc32(&[9u8; 100])).unwrap();
        f.free(slot2);
        assert_eq!(f.live_slots(), 1);
    }

    #[test]
    fn unknown_slot_rejected() {
        let mut f = SpillFile::temp(&Registry::new()).unwrap();
        assert!(f.read(42).is_err());
        assert!(f.write(&[]).is_err());
    }

    #[test]
    fn temp_file_removed_on_drop() {
        let path;
        {
            let mut f = SpillFile::temp(&Registry::new()).unwrap();
            f.write(&[1, 2, 3]).unwrap();
            path = f.path().to_path_buf();
            assert!(path.exists());
        }
        assert!(!path.exists(), "temp spill file not cleaned up");
    }
}
