//! Interleaved rANS decoder.

use super::table::{FreqTable, SCALE, SCALE_BITS};
use super::{FLUSH_BYTES, INTERLEAVE, RANS_L};
use crate::error::{Error, Result};

/// Decodes payloads produced by [`super::RansEncoder`].
///
/// Construction precomputes a 4 KiB slot→symbol lookup table (one byte per
/// normalized probability slot), so the per-symbol loop is a mask, a table
/// load, one multiply, and a branch-predictable renormalization — no
/// bit-by-bit tree walk, which is what makes this backend faster to decode
/// than table-walk Huffman on skewed streams.
#[derive(Debug)]
pub struct RansDecoder {
    freq: [u16; 256],
    cum: [u16; 256],
    /// Slot → symbol map covering `[0, SCALE)`.
    slot_sym: Vec<u8>,
}

impl RansDecoder {
    /// Decoder for `table`.
    pub fn new(table: &FreqTable) -> Self {
        let mut freq = [0u16; 256];
        let mut cum = [0u16; 256];
        let mut slot_sym = vec![0u8; SCALE as usize];
        for s in 0..256usize {
            let f = table.freq(s as u8);
            freq[s] = f;
            cum[s] = table.cum(s as u8);
            let start = cum[s] as usize;
            for slot in slot_sym.iter_mut().skip(start).take(f as usize) {
                *slot = s as u8;
            }
        }
        RansDecoder { freq, cum, slot_sym }
    }

    /// Decode exactly `n_symbols` bytes from `payload`.
    ///
    /// Verifies the full coder invariant: every renormalization byte must be
    /// consumed and every state must return to its initial value, so
    /// truncated or bit-flipped payloads are rejected here even before the
    /// chunk CRC gets a say.
    pub fn decode(&self, payload: &[u8], n_symbols: usize) -> Result<Vec<u8>> {
        if n_symbols == 0 {
            if !payload.is_empty() {
                return Err(Error::Rans("payload bytes for an empty stream".into()));
            }
            return Ok(Vec::new());
        }
        if payload.len() < FLUSH_BYTES {
            return Err(Error::Rans("payload shorter than the state flush".into()));
        }
        let mut states = [0u32; INTERLEAVE];
        for (i, st) in states.iter_mut().enumerate() {
            *st = u32::from_le_bytes(payload[i * 4..i * 4 + 4].try_into().unwrap());
            // The coder keeps states in [L, L << 8); anything else is
            // corruption. The upper bound also keeps the decode-step
            // multiply below 2^31, so it cannot overflow.
            if *st < RANS_L || *st >= (RANS_L << 8) {
                return Err(Error::Rans(format!("initial state {i} outside coder range")));
            }
        }
        let mut pos = FLUSH_BYTES;
        let mut out = Vec::with_capacity(n_symbols);
        for j in 0..n_symbols {
            let lane = j % INTERLEAVE;
            let x = states[lane];
            let slot = x & (SCALE - 1);
            let s = self.slot_sym[slot as usize];
            let f = self.freq[s as usize] as u32;
            let mut x = f * (x >> SCALE_BITS) + slot - self.cum[s as usize] as u32;
            while x < RANS_L {
                let Some(&b) = payload.get(pos) else {
                    return Err(Error::Rans("renormalization bytes exhausted".into()));
                };
                pos += 1;
                x = (x << 8) | b as u32;
            }
            states[lane] = x;
            out.push(s);
        }
        if pos != payload.len() {
            return Err(Error::Rans(format!(
                "{} unconsumed payload bytes",
                payload.len() - pos
            )));
        }
        if states.iter().any(|&x| x != RANS_L) {
            return Err(Error::Rans("final states do not match the initial seed".into()));
        }
        Ok(out)
    }
}
