//! Interleaved rANS encoder.

use super::table::{FreqTable, SCALE_BITS};
use super::{FLUSH_BYTES, INTERLEAVE, RANS_L};
use crate::error::{Error, Result};

/// Encodes byte streams against a [`FreqTable`] with [`INTERLEAVE`]
/// independent 32-bit states.
///
/// Symbol `j` is coded by state `j % INTERLEAVE`; the encoder walks the
/// input *backwards* (rANS is a stack) emitting renormalization bytes into a
/// scratch buffer, then writes the final states followed by the scratch
/// bytes reversed — so the decoder reads states first and renormalization
/// bytes strictly forward.
#[derive(Debug)]
pub struct RansEncoder<'a> {
    table: &'a FreqTable,
}

impl<'a> RansEncoder<'a> {
    /// Encoder over `table`.
    pub fn new(table: &'a FreqTable) -> Self {
        RansEncoder { table }
    }

    /// Encode `symbols`. Empty input yields an empty payload; otherwise the
    /// payload starts with [`FLUSH_BYTES`] bytes of final state.
    ///
    /// Errors if a symbol has zero frequency in the table (the table must be
    /// built from — or cover — the data's histogram).
    pub fn encode(&self, symbols: &[u8]) -> Result<Vec<u8>> {
        if symbols.is_empty() {
            return Ok(Vec::new());
        }
        let mut states = [RANS_L; INTERLEAVE];
        // Renormalization bytes, emitted in reverse decode order.
        let mut rev = Vec::with_capacity(symbols.len() / 2 + 16);
        for j in (0..symbols.len()).rev() {
            let s = symbols[j];
            let f = self.table.freq(s) as u32;
            if f == 0 {
                return Err(Error::Rans(format!("symbol {s} has zero frequency")));
            }
            let c = self.table.cum(s) as u32;
            let mut x = states[j % INTERLEAVE];
            // Renormalize down so the coding step cannot overflow 31 bits.
            let x_max = ((RANS_L >> SCALE_BITS) << 8) * f;
            while x >= x_max {
                rev.push((x & 0xFF) as u8);
                x >>= 8;
            }
            states[j % INTERLEAVE] = ((x / f) << SCALE_BITS) + (x % f) + c;
        }
        let mut out = Vec::with_capacity(FLUSH_BYTES + rev.len());
        for st in states {
            out.extend_from_slice(&st.to_le_bytes());
        }
        out.extend(rev.iter().rev());
        Ok(out)
    }
}
