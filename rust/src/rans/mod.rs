//! Interleaved range Asymmetric Numeral System (rANS) entropy coding over
//! byte alphabets — the codec's second entropy backend.
//!
//! Canonical Huffman (the [`crate::huffman`] backend) pays an integer-bit
//! floor: no symbol can cost less than one bit, so the heavily concentrated
//! exponent histograms of FP8/FP4 streams (often < 1 bit/symbol of entropy)
//! leave real compression on the table. rANS codes at fractional-bit
//! granularity — within ~0.1% of the order-0 entropy — while its decoder
//! inner loop is a masked table load plus one multiply, with no per-bit
//! branching.
//!
//! Design choices:
//!
//! * **32-bit renormalizing states** (ryg-style `rans_byte`): state stays in
//!   `[2^23, 2^31)`, renormalizing one byte at a time.
//! * **[`INTERLEAVE`]-way interleaving**: symbol `j` is coded by state
//!   `j % INTERLEAVE`, breaking the serial dependency chain so the decode
//!   loop pipelines. The lane schedule is part of the wire format.
//! * **12-bit normalized frequencies** ([`SCALE`]): matches the Huffman
//!   backend's 12-bit decoder budget; the slot→symbol LUT is 4 KiB.
//! * **Compact tables**: only present symbols are serialized (delta-coded
//!   symbol + varint frequency), so a 4-symbol FP4 exponent table costs
//!   ~10 bytes against Huffman's fixed 128.
//!
//! Like the rest of the crate, the module is dependency-free.
//!
//! ```
//! use zipnn_lp::rans::{encode_with_table, decode_with_table};
//!
//! let data = b"aaaaaaaabbbbccd".to_vec();
//! let (table, payload) = encode_with_table(&data).unwrap();
//! let decoded = decode_with_table(&table, &payload, data.len()).unwrap();
//! assert_eq!(decoded, data);
//! ```

mod decoder;
mod encoder;
mod table;

pub use decoder::RansDecoder;
pub use encoder::RansEncoder;
pub use table::{FreqTable, SCALE, SCALE_BITS};

use crate::entropy::Histogram;
use crate::error::Result;

/// Number of interleaved coder states. Fixed by the wire format.
pub const INTERLEAVE: usize = 4;

/// Bytes of final-state flush at the head of every non-empty payload
/// (`INTERLEAVE` little-endian `u32`s).
pub const FLUSH_BYTES: usize = INTERLEAVE * 4;

/// Conservative estimate of a serialized [`FreqTable`]'s size in bytes for
/// an alphabet of `distinct` present symbols: the count header plus a
/// delta-coded symbol and a varint frequency per symbol (≤ ~3.5 bytes
/// each). Lives here, next to [`FreqTable::serialize`], so the estimate
/// cannot drift from the wire format; the entropy gate consumes it via
/// [`crate::entropy::rans_table_overhead_bytes`].
pub fn table_overhead_estimate_bytes(distinct: usize) -> f64 {
    2.0 + 3.5 * distinct as f64
}

/// Renormalization lower bound: states live in `[RANS_L, RANS_L << 8)`.
pub(crate) const RANS_L: u32 = 1 << 23;

/// A sound lower bound on the encoded payload size, in bytes, for
/// `n_symbols` of data whose cross-entropy against the table is `cost_bits`
/// ([`FreqTable::cost_bits`]).
///
/// Per state, the flushed 32 bits hold between 23 and 31 bits of accumulated
/// information, so the payload is close to `cost_bits/8 + [12, 16]` bytes.
/// The coder's integer divisions additionally leak at most
/// `log2(1 + 2^-11) < 0.0008` bits per symbol and per renormalization byte;
/// the `n_symbols / 4096` term over-covers that drift threefold. The
/// auto-selector uses this bound to skip a measured rANS encode when
/// Huffman's exact cost already wins — provably, not heuristically.
pub fn payload_lower_bound_bytes(cost_bits: f64, n_symbols: usize) -> usize {
    let ideal = (cost_bits / 8.0).floor() as usize + FLUSH_BYTES - 4;
    ideal.saturating_sub(8 + n_symbols / 4096)
}

/// One-shot: build a table from the data itself and encode. Returns
/// `(table_bytes, payload_bytes)`.
pub fn encode_with_table(data: &[u8]) -> Result<(Vec<u8>, Vec<u8>)> {
    let table = FreqTable::from_histogram(&Histogram::from_bytes(data))?;
    let payload = RansEncoder::new(&table).encode(data)?;
    Ok((table.serialize(), payload))
}

/// One-shot inverse of [`encode_with_table`].
pub fn decode_with_table(table_bytes: &[u8], payload: &[u8], n_symbols: usize) -> Result<Vec<u8>> {
    let table = FreqTable::deserialize(table_bytes)?;
    RansDecoder::new(&table).decode(payload, n_symbols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8]) {
        let (tbl, payload) = encode_with_table(data).unwrap();
        let out = decode_with_table(&tbl, &payload, data.len()).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn roundtrip_skewed() {
        let mut rng = Rng::new(1);
        let data: Vec<u8> = (0..10_000)
            .map(|_| {
                let r = rng.next_f64();
                if r < 0.5 {
                    120
                } else if r < 0.8 {
                    121
                } else if r < 0.95 {
                    119
                } else {
                    rng.below(256) as u8
                }
            })
            .collect();
        roundtrip(&data);
    }

    #[test]
    fn roundtrip_uniform_random() {
        let mut rng = Rng::new(2);
        let mut data = vec![0u8; 5000];
        rng.fill_bytes(&mut data);
        roundtrip(&data);
    }

    #[test]
    fn roundtrip_short_lengths_cover_lane_remainders() {
        // Lengths around the interleave factor exercise lanes that code
        // zero, one, and several symbols.
        let mut rng = Rng::new(3);
        for len in 0..40usize {
            let data: Vec<u8> = (0..len).map(|_| rng.below(7) as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn roundtrip_single_symbol() {
        roundtrip(&[9u8; 777]);
        roundtrip(&[0u8; 1]);
    }

    #[test]
    fn roundtrip_all_256_symbols() {
        let data: Vec<u8> = (0..=255u8).cycle().take(2560).collect();
        roundtrip(&data);
    }

    #[test]
    fn empty_stream() {
        let (_, payload) = encode_with_table(&[1u8]).unwrap(); // table needs data
        let table = FreqTable::from_histogram(&crate::entropy::Histogram::from_bytes(&[1])).unwrap();
        let dec = RansDecoder::new(&table);
        assert_eq!(RansEncoder::new(&table).encode(&[]).unwrap(), Vec::<u8>::new());
        assert_eq!(dec.decode(&[], 0).unwrap(), Vec::<u8>::new());
        // Non-empty payload with zero symbols is rejected.
        assert!(dec.decode(&payload, 0).is_err());
    }

    #[test]
    fn compressed_size_beats_huffman_floor() {
        // 97/1/1/1 four-symbol stream: H ≈ 0.28 bits/sym, but Huffman cannot
        // go below 1 bit for the dominant symbol. rANS must get well under.
        let mut rng = Rng::new(5);
        let data: Vec<u8> = (0..100_000)
            .map(|_| {
                let r = rng.next_f64();
                if r < 0.97 {
                    1u8
                } else if r < 0.98 {
                    2
                } else if r < 0.99 {
                    3
                } else {
                    4
                }
            })
            .collect();
        let (tbl, payload) = encode_with_table(&data).unwrap();
        let bits_per_sym = (tbl.len() + payload.len()) as f64 * 8.0 / data.len() as f64;
        assert!(bits_per_sym < 0.45, "rANS spent {bits_per_sym} bits/sym");
        let (htbl, hpay) = crate::huffman::encode_with_table(&data, 12).unwrap();
        assert!(
            tbl.len() + payload.len() < htbl.len() + hpay.len(),
            "rANS {} !< huffman {}",
            tbl.len() + payload.len(),
            htbl.len() + hpay.len()
        );
    }

    #[test]
    fn payload_size_within_lower_bound_window() {
        let mut rng = Rng::new(6);
        for case in 0..30 {
            let spread = 2 + rng.below(200);
            let n = 64 + rng.below(30_000) as usize;
            let data: Vec<u8> = (0..n).map(|_| rng.below(spread) as u8).collect();
            let h = Histogram::from_bytes(&data);
            let t = FreqTable::from_histogram(&h).unwrap();
            let payload = RansEncoder::new(&t).encode(&data).unwrap();
            let lb = payload_lower_bound_bytes(t.cost_bits(&h), data.len());
            assert!(payload.len() >= lb, "case {case}: {} < lb {lb}", payload.len());
            // The bound stays tight: actual is within the slack window.
            assert!(
                payload.len() <= lb + 32 + data.len() / 2048,
                "case {case}: {} vs lb {lb}",
                payload.len()
            );
        }
    }

    #[test]
    fn corruption_is_detected() {
        let mut rng = Rng::new(7);
        let data: Vec<u8> = (0..5000).map(|_| rng.below(16) as u8).collect();
        let (tbl, payload) = encode_with_table(&data).unwrap();
        let mut detected = 0;
        let trials = 200;
        for _ in 0..trials {
            let mut bad = payload.clone();
            let byte = rng.below(bad.len() as u64) as usize;
            bad[byte] ^= 1 << rng.below(8);
            match decode_with_table(&tbl, &bad, data.len()) {
                Err(_) => detected += 1,
                Ok(out) => assert_ne!(out, data, "flip produced identical payload?"),
            }
        }
        // The state-seed + exhaustion invariants catch the large majority of
        // single-bit flips on their own (chunk CRCs catch the rest upstream).
        assert!(detected > trials / 2, "only {detected}/{trials} flips detected");
        // Truncation is always detected.
        assert!(decode_with_table(&tbl, &payload[..payload.len() - 1], data.len()).is_err());
        assert!(decode_with_table(&tbl, &payload[..8], data.len()).is_err());
    }

    #[test]
    fn decode_rejects_wrong_symbol_count() {
        // Two-symbol data: every symbol costs real bits, so a count
        // mismatch must break the state/exhaustion invariants. (A constant
        // stream carries zero information per symbol — counts are not
        // recoverable there, which is why the codec layer stores constants
        // with the dedicated Constant encoding instead.)
        let data: Vec<u8> = (0..1000).map(|i| (i % 2) as u8).collect();
        let (tbl, payload) = encode_with_table(&data).unwrap();
        assert!(decode_with_table(&tbl, &payload, data.len() + 1).is_err());
        assert!(decode_with_table(&tbl, &payload, data.len() - 1).is_err());
    }
}
