//! Normalized frequency tables: construction, 12-bit normalization, and
//! compact serialization.

use crate::entropy::Histogram;
use crate::error::{Error, Result};
use crate::util::varint;

/// Probability precision of the coder: frequencies are normalized so they
/// sum to exactly `1 << SCALE_BITS`.
pub const SCALE_BITS: u32 = 12;

/// The normalization total (4096). Chosen to match the Huffman backend's
/// 12-bit decoder LUT budget: the slot→symbol table is 4 KiB, L1-resident.
pub const SCALE: u32 = 1 << SCALE_BITS;

/// A frequency table normalized to a total of [`SCALE`].
///
/// `freq[s]` is the 12-bit frequency of byte `s` (0 = absent) and `cum[s]`
/// the exclusive prefix sum, so symbol `s` owns slots `cum[s]..cum[s]+freq[s]`
/// of the `[0, SCALE)` range.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FreqTable {
    freq: [u16; 256],
    cum: [u16; 256],
}

impl FreqTable {
    /// Normalize a histogram to a total of exactly [`SCALE`], guaranteeing
    /// every observed symbol a frequency of at least 1 (so it stays
    /// encodable no matter how rare it is).
    pub fn from_histogram(h: &Histogram) -> Result<Self> {
        let total = h.total();
        if total == 0 {
            return Err(Error::Rans("cannot build a table from an empty histogram".into()));
        }
        let counts = h.counts();
        let mut freq = [0u16; 256];
        let mut sum: u32 = 0;
        for s in 0..256 {
            if counts[s] > 0 {
                let scaled =
                    ((counts[s] as u128 * SCALE as u128) / total as u128) as u32;
                let f = scaled.clamp(1, SCALE);
                freq[s] = f as u16;
                sum += f;
            }
        }
        // Fix rounding drift: distribute the difference over the most
        // frequent symbols, where one slot of probability mass distorts the
        // code length least. Both loops touch at most ~256 units (the floor
        // rounding error is bounded by the alphabet size).
        if sum != SCALE {
            let mut order: Vec<usize> = (0..256).filter(|&s| freq[s] > 0).collect();
            order.sort_by_key(|&s| std::cmp::Reverse(counts[s]));
            if sum < SCALE {
                let mut deficit = SCALE - sum;
                'grow: loop {
                    for &s in &order {
                        if deficit == 0 {
                            break 'grow;
                        }
                        freq[s] += 1;
                        deficit -= 1;
                    }
                }
            } else {
                let mut excess = sum - SCALE;
                'shrink: loop {
                    for &s in &order {
                        if excess == 0 {
                            break 'shrink;
                        }
                        if freq[s] > 1 {
                            freq[s] -= 1;
                            excess -= 1;
                        }
                    }
                }
            }
        }
        Ok(Self::from_freqs(freq))
    }

    /// Build from frequencies that already sum to [`SCALE`] (private: the
    /// public constructors validate).
    fn from_freqs(freq: [u16; 256]) -> Self {
        let mut cum = [0u16; 256];
        let mut acc = 0u32;
        for s in 0..256 {
            cum[s] = acc as u16;
            acc += freq[s] as u32;
        }
        debug_assert_eq!(acc, SCALE);
        FreqTable { freq, cum }
    }

    /// Normalized frequency of `sym` (0 if absent).
    #[inline]
    pub fn freq(&self, sym: u8) -> u16 {
        self.freq[sym as usize]
    }

    /// Exclusive cumulative frequency of `sym`.
    #[inline]
    pub fn cum(&self, sym: u8) -> u16 {
        self.cum[sym as usize]
    }

    /// Number of symbols with a non-zero frequency.
    pub fn distinct(&self) -> usize {
        self.freq.iter().filter(|&&f| f > 0).count()
    }

    /// Whether every symbol of `hist` is encodable with this table.
    pub fn covers(&self, hist: &Histogram) -> bool {
        hist.counts()
            .iter()
            .enumerate()
            .all(|(s, &c)| c == 0 || self.freq[s] > 0)
    }

    /// Exact expected payload cost in bits for data with histogram `hist`
    /// (the cross-entropy of `hist` against the normalized model), ignoring
    /// the constant per-stream flush. Infinite if the table does not cover
    /// the histogram.
    pub fn cost_bits(&self, hist: &Histogram) -> f64 {
        let mut bits = 0.0;
        for (s, &c) in hist.counts().iter().enumerate() {
            if c == 0 {
                continue;
            }
            if self.freq[s] == 0 {
                return f64::INFINITY;
            }
            bits += c as f64 * (SCALE as f64 / self.freq[s] as f64).log2();
        }
        bits
    }

    /// Serialize compactly: present-symbol count, then per present symbol
    /// (ascending) the delta from the previous symbol and `freq - 1`, all as
    /// varints. Skewed exponent alphabets (a handful of symbols) cost a few
    /// bytes, not the Huffman table's fixed 128.
    pub fn serialize(&self) -> Vec<u8> {
        let present: Vec<usize> = (0..256).filter(|&s| self.freq[s] > 0).collect();
        let mut out = Vec::with_capacity(2 + present.len() * 3);
        varint::write_usize(&mut out, present.len());
        let mut prev = 0usize;
        for (i, &s) in present.iter().enumerate() {
            let delta = if i == 0 { s } else { s - prev };
            varint::write_usize(&mut out, delta);
            varint::write_u64(&mut out, (self.freq[s] - 1) as u64);
            prev = s;
        }
        out
    }

    /// Inverse of [`serialize`](Self::serialize). Rejects tables whose
    /// symbols are not strictly increasing or whose frequencies do not sum
    /// to exactly [`SCALE`].
    pub fn deserialize(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let n_present = varint::read_usize(buf, &mut pos)?;
        if n_present == 0 || n_present > 256 {
            return Err(Error::Rans(format!("implausible symbol count {n_present}")));
        }
        let mut freq = [0u16; 256];
        let mut sym = 0usize;
        let mut sum = 0u32;
        for i in 0..n_present {
            let delta = varint::read_usize(buf, &mut pos)?;
            if i == 0 {
                sym = delta;
            } else {
                if delta == 0 {
                    return Err(Error::Rans("symbols not strictly increasing".into()));
                }
                sym += delta;
            }
            if sym > 255 {
                return Err(Error::Rans(format!("symbol {sym} out of range")));
            }
            let f = varint::read_u64(buf, &mut pos)? + 1;
            if f > SCALE as u64 {
                return Err(Error::Rans(format!("frequency {f} exceeds scale")));
            }
            freq[sym] = f as u16;
            sum += f as u32;
        }
        if pos != buf.len() {
            return Err(Error::Rans("trailing bytes after frequency table".into()));
        }
        if sum != SCALE {
            return Err(Error::Rans(format!("frequencies sum to {sum}, need {SCALE}")));
        }
        Ok(Self::from_freqs(freq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn normalization_sums_to_scale() {
        let mut rng = Rng::new(1);
        for case in 0..50 {
            let n = 1 + rng.below(20_000) as usize;
            let spread = 1 + rng.below(256);
            let data: Vec<u8> = (0..n).map(|_| rng.below(spread) as u8).collect();
            let t = FreqTable::from_histogram(&Histogram::from_bytes(&data)).unwrap();
            let sum: u32 = (0..=255u8).map(|s| t.freq(s) as u32).sum();
            assert_eq!(sum, SCALE, "case {case}");
            // Every observed symbol keeps a non-zero frequency.
            let h = Histogram::from_bytes(&data);
            assert!(t.covers(&h), "case {case}");
        }
    }

    #[test]
    fn single_symbol_takes_all_mass() {
        let t = FreqTable::from_histogram(&Histogram::from_bytes(&[7u8; 100])).unwrap();
        assert_eq!(t.freq(7), SCALE as u16);
        assert_eq!(t.cum(7), 0);
        assert_eq!(t.distinct(), 1);
    }

    #[test]
    fn empty_histogram_rejected() {
        assert!(FreqTable::from_histogram(&Histogram::new()).is_err());
    }

    #[test]
    fn rare_symbols_survive_normalization() {
        // 4095 copies of one symbol + 1 of another: the rare one must keep
        // freq >= 1 to stay encodable.
        let mut data = vec![1u8; 100_000];
        data.push(200);
        let t = FreqTable::from_histogram(&Histogram::from_bytes(&data)).unwrap();
        assert!(t.freq(200) >= 1);
        assert_eq!(t.freq(1) as u32 + t.freq(200) as u32, SCALE);
    }

    #[test]
    fn serialize_roundtrip() {
        let mut rng = Rng::new(3);
        for case in 0..30 {
            let spread = 1 + rng.below(256);
            let data: Vec<u8> =
                (0..5000).map(|_| (rng.below(spread)) as u8).collect();
            let t = FreqTable::from_histogram(&Histogram::from_bytes(&data)).unwrap();
            let ser = t.serialize();
            let t2 = FreqTable::deserialize(&ser).unwrap();
            assert_eq!(t, t2, "case {case}");
        }
    }

    #[test]
    fn compact_for_small_alphabets() {
        // 4 distinct symbols: far below the Huffman table's fixed 128 bytes.
        let data: Vec<u8> = (0..10_000).map(|i| 120 + (i % 4) as u8).collect();
        let t = FreqTable::from_histogram(&Histogram::from_bytes(&data)).unwrap();
        assert!(t.serialize().len() <= 16, "table {} bytes", t.serialize().len());
    }

    #[test]
    fn deserialize_rejects_garbage() {
        assert!(FreqTable::deserialize(&[]).is_err());
        assert!(FreqTable::deserialize(&[0]).is_err()); // zero symbols
        // One symbol with freq 1 != SCALE.
        let mut buf = Vec::new();
        varint::write_usize(&mut buf, 1);
        varint::write_usize(&mut buf, 5);
        varint::write_u64(&mut buf, 0);
        assert!(FreqTable::deserialize(&buf).is_err());
        // Trailing bytes after a valid table.
        let good = FreqTable::from_histogram(&Histogram::from_bytes(&[1u8, 1, 2])).unwrap();
        let mut ser = good.serialize();
        ser.push(0);
        assert!(FreqTable::deserialize(&ser).is_err());
        // Duplicate symbol (delta 0 after the first).
        let mut dup = Vec::new();
        varint::write_usize(&mut dup, 2);
        varint::write_usize(&mut dup, 3);
        varint::write_u64(&mut dup, 2047);
        varint::write_usize(&mut dup, 0);
        varint::write_u64(&mut dup, 2047);
        assert!(FreqTable::deserialize(&dup).is_err());
    }

    #[test]
    fn cost_bits_matches_cross_entropy() {
        // Uniform over 2 symbols normalized to 2048/2048: exactly 1 bit/sym.
        let data: Vec<u8> = (0..4096).map(|i| (i % 2) as u8).collect();
        let h = Histogram::from_bytes(&data);
        let t = FreqTable::from_histogram(&h).unwrap();
        assert!((t.cost_bits(&h) - 4096.0).abs() < 1e-9);
        // Uncovered histogram costs infinity.
        let other = Histogram::from_bytes(&[9u8; 10]);
        assert!(t.cost_bits(&other).is_infinite());
        assert!(!t.covers(&other));
    }
}
