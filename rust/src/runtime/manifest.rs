//! Parsing of the AOT `manifest.json` emitted by `python/compile/aot.py`.

use super::DType;
use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// One input/output slot in an artifact signature.
#[derive(Clone, Debug)]
pub struct IoSpec {
    /// Slot name ("tokens", "k_cache", weight names, …; outputs unnamed).
    pub name: String,
    /// Element dtype.
    pub dtype: DType,
    /// Static shape.
    pub shape: Vec<usize>,
}

impl IoSpec {
    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True when the slot is zero-sized.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One artifact's file + signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// HLO text file name within the artifact directory.
    pub file: String,
    /// Positional inputs.
    pub inputs: Vec<IoSpec>,
    /// Tuple outputs, in order.
    pub outputs: Vec<IoSpec>,
}

/// Model dimensions recorded by the exporter.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head width.
    pub head_dim: usize,
    /// Max sequence length (cache rows).
    pub max_seq: usize,
    /// Batch size baked into the artifacts.
    pub batch: usize,
    /// Element count of the standalone kernel artifacts.
    pub kernel_n: usize,
}

/// The full parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Model dimensions.
    pub dims: ModelDims,
    /// Weight names in canonical (positional) order.
    pub weight_names: Vec<String>,
    /// Weight shapes keyed by name.
    pub weight_shapes: BTreeMap<String, Vec<usize>>,
    /// Artifacts keyed by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Initial-weights file name (flat f32, manifest order), if exported.
    pub weights_file: Option<String>,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| Error::Runtime(format!("manifest.json: {e}")))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let cfg = j.field("config")?;
        let u = |k: &str| -> Result<usize> {
            cfg.field(k)?
                .as_usize()
                .ok_or_else(|| Error::Runtime(format!("config.{k} not a usize")))
        };
        let dims = ModelDims {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            head_dim: u("head_dim")?,
            max_seq: u("max_seq")?,
            batch: u("batch")?,
            kernel_n: u("kernel_n")?,
        };
        let weight_names: Vec<String> = j
            .field("weight_names")?
            .as_arr()
            .ok_or_else(|| Error::Runtime("weight_names not an array".into()))?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let mut weight_shapes = BTreeMap::new();
        for (name, shape) in j
            .field("weight_shapes")?
            .as_obj()
            .ok_or_else(|| Error::Runtime("weight_shapes not an object".into()))?
        {
            weight_shapes.insert(name.clone(), parse_shape(shape)?);
        }
        let mut artifacts = BTreeMap::new();
        for (name, art) in j
            .field("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Runtime("artifacts not an object".into()))?
        {
            artifacts.insert(name.clone(), parse_artifact(art)?);
        }
        let weights_file = j.get("weights_file").and_then(|v| v.as_str()).map(String::from);
        Ok(Manifest { dims, weight_names, weight_shapes, artifacts, weights_file })
    }

    /// Load the initial weights file as per-weight f32 vectors in canonical
    /// order.
    pub fn load_initial_weights(&self, dir: &Path) -> Result<Vec<Vec<f32>>> {
        let file = self
            .weights_file
            .as_ref()
            .ok_or_else(|| Error::Runtime("manifest has no weights_file".into()))?;
        let bytes = std::fs::read(dir.join(file))?;
        let mut out = Vec::with_capacity(self.weight_names.len());
        let mut off = 0usize;
        for name in &self.weight_names {
            let shape = self
                .weight_shapes
                .get(name)
                .ok_or_else(|| Error::Runtime(format!("no shape for weight {name}")))?;
            let n: usize = shape.iter().product();
            let end = off + n * 4;
            if end > bytes.len() {
                return Err(Error::Runtime("weights file truncated".into()));
            }
            out.push(
                bytes[off..end]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
            off = end;
        }
        if off != bytes.len() {
            return Err(Error::Runtime("weights file has trailing bytes".into()));
        }
        Ok(out)
    }
}

fn parse_shape(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()
        .ok_or_else(|| Error::Runtime("shape not an array".into()))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| Error::Runtime("bad shape dim".into())))
        .collect()
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    let name = v.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
    let dtype = DType::parse(
        v.field("dtype")?
            .as_str()
            .ok_or_else(|| Error::Runtime("dtype not a string".into()))?,
    )?;
    let shape = parse_shape(v.field("shape")?)?;
    Ok(IoSpec { name, dtype, shape })
}

fn parse_artifact(v: &Json) -> Result<ArtifactSpec> {
    let file = v
        .field("file")?
        .as_str()
        .ok_or_else(|| Error::Runtime("file not a string".into()))?
        .to_string();
    let inputs = v
        .field("inputs")?
        .as_arr()
        .ok_or_else(|| Error::Runtime("inputs not an array".into()))?
        .iter()
        .map(parse_io)
        .collect::<Result<_>>()?;
    let outputs = v
        .field("outputs")?
        .as_arr()
        .ok_or_else(|| Error::Runtime("outputs not an array".into()))?
        .iter()
        .map(parse_io)
        .collect::<Result<_>>()?;
    Ok(ArtifactSpec { file, inputs, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"vocab": 32, "d_model": 16, "n_layers": 1, "n_heads": 2,
                 "head_dim": 8, "max_seq": 8, "batch": 2, "kernel_n": 1024},
      "weight_names": ["embed", "ln_f"],
      "weight_shapes": {"embed": [32, 16], "ln_f": [16]},
      "artifacts": {
        "prefill": {
          "file": "prefill.hlo.txt",
          "inputs": [
            {"name": "embed", "dtype": "float32", "shape": [32, 16]},
            {"name": "tokens", "dtype": "int32", "shape": [2, 8]}
          ],
          "outputs": [{"dtype": "float32", "shape": [2, 8, 32]}]
        }
      },
      "weights_file": "weights_init.bin"
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.dims.vocab, 32);
        assert_eq!(m.dims.head_dim, 8);
        assert_eq!(m.weight_names, vec!["embed", "ln_f"]);
        assert_eq!(m.weight_shapes["embed"], vec![32, 16]);
        let art = &m.artifacts["prefill"];
        assert_eq!(art.inputs[1].dtype, DType::I32);
        assert_eq!(art.outputs[0].shape, vec![2, 8, 32]);
        assert_eq!(m.weights_file.as_deref(), Some("weights_init.bin"));
    }

    #[test]
    fn missing_field_errors() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"config": {}}"#).is_err());
    }

    #[test]
    fn initial_weights_roundtrip() {
        let dir = std::env::temp_dir().join(format!("zipnn_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        // 32*16 + 16 floats.
        let total = 32 * 16 + 16;
        let vals: Vec<f32> = (0..total).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("weights_init.bin"), &bytes).unwrap();
        let w = m.load_initial_weights(&dir).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].len(), 512);
        assert_eq!(w[1][15], 527.0);
        // Truncated file errors.
        std::fs::write(dir.join("weights_init.bin"), &bytes[..100]).unwrap();
        assert!(m.load_initial_weights(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
