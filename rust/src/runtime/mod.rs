//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU client. The only bridge between the Rust coordinator and the
//! JAX/Pallas compute — Python never runs here.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`, with the
//! jax side having lowered everything `return_tuple=True` so every artifact
//! yields one tuple literal.
//!
//! The execution half (`Engine` and the literal conversions) needs the
//! `xla` binding crate and is gated behind the **`pjrt`** cargo feature so
//! the compression stack builds with no GPU runtime and no external
//! dependencies. The manifest parser, [`DType`], and [`HostTensor`] are
//! always available — the coordinator's batching logic and the mock-model
//! property tests use them without PJRT.

mod manifest;

pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelDims};

use crate::error::{Error, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::Path;

/// Element dtypes appearing in artifact signatures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed int.
    I32,
    /// Unsigned byte.
    U8,
    /// Unsigned 16-bit (BF16 carrier for the split kernel).
    U16,
}

impl DType {
    /// Parse the manifest's numpy dtype string.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "uint8" => Ok(DType::U8),
            "uint16" => Ok(DType::U16),
            other => Err(Error::Runtime(format!("unsupported dtype '{other}'"))),
        }
    }

    #[cfg(feature = "pjrt")]
    fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U8 => xla::ElementType::U8,
            DType::U16 => xla::ElementType::U16,
        }
    }

    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U16 => 2,
            DType::U8 => 1,
        }
    }
}

/// A host tensor crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub struct HostTensor {
    /// Element type.
    pub dtype: DType,
    /// Shape.
    pub shape: Vec<usize>,
    /// Little-endian raw bytes, C-contiguous.
    pub data: Vec<u8>,
}

impl HostTensor {
    /// From f32 values.
    pub fn f32(values: &[f32], shape: &[usize]) -> Self {
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        HostTensor { dtype: DType::F32, shape: shape.to_vec(), data }
    }

    /// From i32 values.
    pub fn i32(values: &[i32], shape: &[usize]) -> Self {
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        HostTensor { dtype: DType::I32, shape: shape.to_vec(), data }
    }

    /// From raw u8 bytes.
    pub fn u8(values: &[u8], shape: &[usize]) -> Self {
        HostTensor { dtype: DType::U8, shape: shape.to_vec(), data: values.to_vec() }
    }

    /// From u16 values.
    pub fn u16(values: &[u16], shape: &[usize]) -> Self {
        let data = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        HostTensor { dtype: DType::U16, shape: shape.to_vec(), data }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f32 values (dtype must be F32).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            return Err(Error::Runtime(format!("tensor is {:?}, not F32", self.dtype)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// View as i32 values.
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            return Err(Error::Runtime(format!("tensor is {:?}, not I32", self.dtype)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            self.dtype.element_type(),
            &self.shape,
            &self.data,
        )
        .map_err(|e| Error::Runtime(format!("literal creation failed: {e}")))
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit
            .array_shape()
            .map_err(|e| Error::Runtime(format!("literal shape: {e}")))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let dtype = match shape.ty() {
            xla::ElementType::F32 => DType::F32,
            xla::ElementType::S32 => DType::I32,
            xla::ElementType::U8 => DType::U8,
            xla::ElementType::U16 => DType::U16,
            other => return Err(Error::Runtime(format!("unsupported output type {other:?}"))),
        };
        // copy_raw_to is typed, so dispatch per dtype and re-serialize LE.
        let data: Vec<u8> = match dtype {
            DType::F32 => lit
                .to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("literal copy: {e}")))?
                .iter()
                .flat_map(|v| v.to_le_bytes())
                .collect(),
            DType::I32 => lit
                .to_vec::<i32>()
                .map_err(|e| Error::Runtime(format!("literal copy: {e}")))?
                .iter()
                .flat_map(|v| v.to_le_bytes())
                .collect(),
            DType::U16 => lit
                .to_vec::<u16>()
                .map_err(|e| Error::Runtime(format!("literal copy: {e}")))?
                .iter()
                .flat_map(|v| v.to_le_bytes())
                .collect(),
            DType::U8 => lit
                .to_vec::<u8>()
                .map_err(|e| Error::Runtime(format!("literal copy: {e}")))?,
        };
        Ok(HostTensor { dtype, shape: dims, data })
    }
}

/// A compiled artifact plus its manifest spec.
#[cfg(feature = "pjrt")]
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    /// Signature from the manifest.
    pub spec: ArtifactSpec,
}

/// The PJRT engine: one CPU client + every compiled artifact.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: HashMap<String, Artifact>,
    /// Parsed manifest (model dims, weight names).
    pub manifest: Manifest,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load every artifact listed in `<dir>/manifest.json` and compile it
    /// on a fresh CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT client: {e}")))?;
        let mut artifacts = HashMap::new();
        for (name, spec) in &manifest.artifacts {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", spec.file)))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {}: {e}", spec.file)))?;
            artifacts.insert(name.clone(), Artifact { exe, spec: spec.clone() });
        }
        Ok(Engine { client, artifacts, manifest })
    }

    /// PJRT platform name (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    /// Execute `name` with positional inputs; returns the flattened tuple
    /// outputs. Validates shapes/dtypes against the manifest signature.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?;
        if inputs.len() != art.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                art.spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (t, spec)) in inputs.iter().zip(&art.spec.inputs).enumerate() {
            if t.shape != spec.shape || t.dtype != spec.dtype {
                return Err(Error::Runtime(format!(
                    "{name} input {i} ('{}'): expected {:?}{:?}, got {:?}{:?}",
                    spec.name, spec.dtype, spec.shape, t.dtype, t.shape
                )));
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("{name} execute: {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("{name} fetch: {e}")))?;
        let parts = out
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("{name} untuple: {e}")))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_roundtrip() {
        for (s, d) in [
            ("float32", DType::F32),
            ("int32", DType::I32),
            ("uint8", DType::U8),
            ("uint16", DType::U16),
        ] {
            assert_eq!(DType::parse(s).unwrap(), d);
        }
        assert!(DType::parse("float64").is_err());
    }

    #[test]
    fn host_tensor_roundtrips() {
        let t = HostTensor::f32(&[1.0, -2.5, 3.25], &[3]);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, -2.5, 3.25]);
        assert!(t.as_i32().is_err());
        let t = HostTensor::i32(&[1, -2], &[2]);
        assert_eq!(t.as_i32().unwrap(), vec![1, -2]);
        let t = HostTensor::u8(&[7, 8], &[2]);
        assert_eq!(t.data, vec![7, 8]);
        assert_eq!(t.len(), 2);
    }
}
