//! Minimal HTTP/1.1 plumbing for the distribution server: a bounded,
//! deadline-guarded request reader, a strict request parser, `Range:`
//! header interpretation, and response-head rendering.
//!
//! Only what serving archives needs is implemented — GET/HEAD requests
//! without bodies, single byte ranges, `Connection: close` responses — and
//! everything a client can get wrong maps to a typed [`RequestError`] the
//! server turns into the right 4xx status. The parser is pure (bytes in,
//! [`Request`] out), so the malformed-input matrix is unit-testable without
//! a socket.

use std::io::Read;
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Upper bound on a request head (request line + headers + terminator).
/// Requests still growing past this are answered `431` — an unbounded
/// buffer would let one slow client allocate without limit.
pub const MAX_REQUEST_BYTES: usize = 16 * 1024;

/// How a request failed before a route was even resolved. Each variant maps
/// to one response status (see [`RequestError::status`]).
#[derive(Debug)]
pub enum RequestError {
    /// The client closed (or broke) the connection before completing a
    /// request head. No response can be delivered; the connection slot is
    /// simply released.
    Disconnected,
    /// The head did not complete before the read deadline — the slow-loris
    /// guard. Answered `408`.
    Timeout,
    /// The head outgrew [`MAX_REQUEST_BYTES`]. Answered `431`.
    TooLarge,
    /// Syntactically invalid request line or header. Answered `400`.
    Malformed(String),
}

impl RequestError {
    /// The response status this failure is answered with (`None` when the
    /// client is already gone and no response can be delivered).
    pub fn status(&self) -> Option<u16> {
        match self {
            RequestError::Disconnected => None,
            RequestError::Timeout => Some(408),
            RequestError::TooLarge => Some(431),
            RequestError::Malformed(_) => Some(400),
        }
    }
}

/// One parsed request head.
#[derive(Debug)]
pub struct Request {
    /// Request method, as sent (`GET`, `HEAD`, ...).
    pub method: String,
    /// Request target (`/models/llama.zlp`), percent-encoding untouched —
    /// archive names are restricted to characters that need none.
    pub target: String,
    /// Header `(name, value)` pairs in arrival order; names lower-cased.
    headers: Vec<(String, String)>,
}

impl Request {
    /// Case-insensitive single-header lookup (first occurrence wins).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }
}

/// Read one request head off `stream`, enforcing the byte bound and an
/// overall deadline (`timeout` from now), then parse it. The read timeout
/// is re-armed with the *remaining* deadline budget before every `read`, so
/// a client trickling one byte per second cannot hold the connection open
/// past `timeout` — the slow-loris guard.
pub fn read_request(
    stream: &mut TcpStream,
    timeout: Duration,
) -> std::result::Result<Request, RequestError> {
    let deadline = Instant::now() + timeout;
    let mut head = Vec::with_capacity(1024);
    let mut buf = [0u8; 1024];
    loop {
        if let Some(end) = find_terminator(&head) {
            // Anything past the terminator would be a request body (or a
            // pipelined request); both are rejected in parse_request via
            // the body-header check, so trailing bytes are simply ignored.
            return parse_request(&head[..end]);
        }
        if head.len() > MAX_REQUEST_BYTES {
            return Err(RequestError::TooLarge);
        }
        let remaining = deadline
            .checked_duration_since(Instant::now())
            .ok_or(RequestError::Timeout)?;
        // set_read_timeout(0) would mean "block forever"; the checked_sub
        // above guarantees remaining > 0 here.
        stream.set_read_timeout(Some(remaining)).map_err(|_| RequestError::Disconnected)?;
        match stream.read(&mut buf) {
            Ok(0) => {
                return Err(if head.is_empty() {
                    RequestError::Disconnected
                } else {
                    RequestError::Malformed("request head ends before the blank line".into())
                });
            }
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(e) => {
                return Err(match e.kind() {
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                        RequestError::Timeout
                    }
                    _ => RequestError::Disconnected,
                });
            }
        }
    }
}

/// Byte offset of the `\r\n\r\n` head terminator, if present.
fn find_terminator(head: &[u8]) -> Option<usize> {
    head.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse a complete request head (everything before the blank line).
/// Strict on purpose: a distribution server gains nothing from guessing at
/// malformed requests, and every rejection is an explicit `400`.
pub fn parse_request(head: &[u8]) -> std::result::Result<Request, RequestError> {
    let text = std::str::from_utf8(head)
        .map_err(|_| RequestError::Malformed("request head is not utf-8".into()))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
            _ => {
                return Err(RequestError::Malformed(format!(
                    "bad request line '{request_line}'"
                )))
            }
        };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(RequestError::Malformed(format!("bad method '{method}'")));
    }
    if !version.starts_with("HTTP/1.") {
        return Err(RequestError::Malformed(format!("unsupported version '{version}'")));
    }
    if !target.starts_with('/') {
        return Err(RequestError::Malformed(format!("bad target '{target}'")));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue; // the blank terminator line
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| RequestError::Malformed(format!("header without ':': '{line}'")))?;
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(RequestError::Malformed(format!("bad header name '{name}'")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
    let request = Request {
        method: method.to_string(),
        target: target.to_string(),
        headers,
    };
    // GET/HEAD carry no body and this server defines no other method, so a
    // declared body is always a protocol error — reject it up front rather
    // than misparse the body bytes as a second request.
    if request.header("content-length").is_some_and(|v| v.trim() != "0")
        || request.header("transfer-encoding").is_some()
    {
        return Err(RequestError::Malformed("request bodies are not supported".into()));
    }
    Ok(request)
}

/// Interpretation of a `Range:` header against a `total`-byte resource.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RangeSpec {
    /// Serve the whole resource (no range, or a range the server elects to
    /// ignore: syntactically invalid or multi-range, per RFC 9110 both may
    /// fall back to a full `200` response).
    Whole,
    /// Serve `len` bytes from `start` as a `206`.
    Single {
        /// First byte offset of the satisfiable range.
        start: u64,
        /// Number of bytes to serve (clamped to the resource end).
        len: u64,
    },
    /// Syntactically valid but unsatisfiable (start at/after EOF, or an
    /// empty suffix): answered `416` with `Content-Range: bytes */total`.
    Unsatisfiable,
}

/// Parse a `Range:` header value (e.g. `bytes=0-1023`, `bytes=512-`,
/// `bytes=-256`) against a resource of `total` bytes.
pub fn parse_range(value: &str, total: u64) -> RangeSpec {
    let Some(spec) = value.trim().strip_prefix("bytes=") else {
        return RangeSpec::Whole; // unknown unit: ignore the header
    };
    if spec.contains(',') {
        return RangeSpec::Whole; // multi-range: full-body fallback
    }
    let spec = spec.trim();
    let Some((lo, hi)) = spec.split_once('-') else {
        return RangeSpec::Whole; // no '-': not a byte-range spec
    };
    if lo.is_empty() {
        // Suffix form: the final N bytes.
        let Ok(n) = hi.parse::<u64>() else {
            return RangeSpec::Whole;
        };
        if n == 0 || total == 0 {
            return RangeSpec::Unsatisfiable;
        }
        let len = n.min(total);
        return RangeSpec::Single { start: total - len, len };
    }
    let Ok(start) = lo.parse::<u64>() else {
        return RangeSpec::Whole;
    };
    if start >= total {
        return RangeSpec::Unsatisfiable;
    }
    if hi.is_empty() {
        // Open-ended form: from `start` to EOF.
        return RangeSpec::Single { start, len: total - start };
    }
    let Ok(end) = hi.parse::<u64>() else {
        return RangeSpec::Whole;
    };
    if end < start {
        return RangeSpec::Whole; // inverted range: invalid, ignore
    }
    let end = end.min(total - 1);
    RangeSpec::Single { start, len: end - start + 1 }
}

/// Canonical reason phrase for the statuses this server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        206 => "Partial Content",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        416 => "Range Not Satisfiable",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Render a response head. Every response is `Connection: close` — one
/// request per connection keeps the worker-slot accounting trivial (a slot
/// is exactly one request) and resumable pulls reconnect with `Range:`
/// anyway.
pub fn response_head(status: u16, headers: &[(&str, String)]) -> String {
    let mut out = format!("HTTP/1.1 {status} {}\r\n", status_reason(status));
    out.push_str("connection: close\r\n");
    for (name, value) in headers {
        out.push_str(name);
        out.push_str(": ");
        out.push_str(value);
        out.push_str("\r\n");
    }
    out.push_str("\r\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> std::result::Result<Request, RequestError> {
        parse_request(s.as_bytes())
    }

    #[test]
    fn parses_request_line_and_headers() {
        let r = parse(
            "GET /models/m.zlp HTTP/1.1\r\nHost: x\r\nRange: bytes=0-5\r\nIf-Range: \"e\"\r\n",
        )
        .unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.target, "/models/m.zlp");
        assert_eq!(r.header("host"), Some("x"));
        assert_eq!(r.header("RANGE"), Some("bytes=0-5"));
        assert_eq!(r.header("if-range"), Some("\"e\""));
        assert_eq!(r.header("absent"), None);
    }

    #[test]
    fn malformed_request_lines_are_rejected() {
        for bad in [
            "",
            "GET",
            "GET /x",
            "GET /x HTTP/1.1 extra",
            "GET  /x HTTP/1.1", // double space -> empty token
            "get /x HTTP/1.1",  // lowercase method token
            "GET x HTTP/1.1",   // target without leading slash
            "GET /x SPDY/3",    // unsupported protocol
        ] {
            assert!(
                matches!(parse(&format!("{bad}\r\n")), Err(RequestError::Malformed(_))),
                "accepted: {bad:?}"
            );
        }
        // Raw bytes that are not utf-8 at all.
        assert!(matches!(
            parse_request(b"GET /\xff\xfe HTTP/1.1\r\n"),
            Err(RequestError::Malformed(_))
        ));
    }

    #[test]
    fn malformed_headers_are_rejected() {
        for bad in [
            "GET /x HTTP/1.1\r\nno-colon-here\r\n",
            "GET /x HTTP/1.1\r\n: empty-name\r\n",
            "GET /x HTTP/1.1\r\nbad name: v\r\n",
        ] {
            assert!(matches!(parse(bad), Err(RequestError::Malformed(_))), "accepted: {bad:?}");
        }
    }

    #[test]
    fn declared_bodies_are_rejected() {
        assert!(matches!(
            parse("GET /x HTTP/1.1\r\nContent-Length: 4\r\n"),
            Err(RequestError::Malformed(_))
        ));
        assert!(matches!(
            parse("GET /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n"),
            Err(RequestError::Malformed(_))
        ));
        // An explicit zero-length body is indistinguishable from no body.
        assert!(parse("GET /x HTTP/1.1\r\nContent-Length: 0\r\n").is_ok());
    }

    #[test]
    fn range_parsing_covers_every_form() {
        let total = 1000;
        assert_eq!(parse_range("bytes=0-99", total), RangeSpec::Single { start: 0, len: 100 });
        assert_eq!(
            parse_range("bytes=900-", total),
            RangeSpec::Single { start: 900, len: 100 }
        );
        assert_eq!(
            parse_range("bytes=-100", total),
            RangeSpec::Single { start: 900, len: 100 }
        );
        // Suffix longer than the resource clamps to the whole resource.
        assert_eq!(
            parse_range("bytes=-5000", total),
            RangeSpec::Single { start: 0, len: 1000 }
        );
        // End past EOF clamps.
        assert_eq!(
            parse_range("bytes=990-4000", total),
            RangeSpec::Single { start: 990, len: 10 }
        );
        // Unsatisfiable: start at/after EOF, empty suffix, empty resource.
        assert_eq!(parse_range("bytes=1000-", total), RangeSpec::Unsatisfiable);
        assert_eq!(parse_range("bytes=2000-3000", total), RangeSpec::Unsatisfiable);
        assert_eq!(parse_range("bytes=-0", total), RangeSpec::Unsatisfiable);
        assert_eq!(parse_range("bytes=-10", 0), RangeSpec::Unsatisfiable);
        // Invalid or unsupported forms fall back to the whole body.
        for fallback in [
            "bytes=0-99,200-299", // multi-range
            "bytes=99-0",         // inverted
            "bytes=abc-def",
            "bytes=",
            "items=0-5", // unknown unit
        ] {
            assert_eq!(parse_range(fallback, total), RangeSpec::Whole, "{fallback}");
        }
    }

    #[test]
    fn response_head_renders_status_and_headers() {
        let head = response_head(206, &[("content-length", "10".to_string())]);
        assert!(head.starts_with("HTTP/1.1 206 Partial Content\r\n"));
        assert!(head.contains("connection: close\r\n"));
        assert!(head.contains("content-length: 10\r\n"));
        assert!(head.ends_with("\r\n\r\n"));
    }

    #[test]
    fn error_statuses_map_per_variant() {
        assert_eq!(RequestError::Disconnected.status(), None);
        assert_eq!(RequestError::Timeout.status(), Some(408));
        assert_eq!(RequestError::TooLarge.status(), Some(431));
        assert_eq!(RequestError::Malformed(String::new()).status(), Some(400));
    }
}
