//! Model-distribution server: ranged, resumable archive pulls over HTTP.
//!
//! The v2 archive's trailing chunk directory already makes the file a
//! random-access artifact; this module puts a **dependency-free HTTP/1.1
//! server** (std [`TcpListener`] + the existing
//! [`WorkerPool`](crate::exec::WorkerPool)) in front of a directory of
//! archives so clients pull models over the network — the paper's headline
//! transmission-cost story, end to end:
//!
//! * `GET /models/<name>` streams the raw archive bytes. On the mmap
//!   backing every connection serves borrowed slices out of the shared page
//!   cache — concurrent pulls of one model cost one copy of the file in
//!   memory, and the read path issues `madvise(SEQUENTIAL)` ahead of the
//!   stream.
//! * `Range: bytes=a-b` maps onto byte-range positioned reads
//!   ([`ArchiveReader::read_file_range`]) with full `206`/`416` semantics,
//!   so an interrupted pull resumes from where it broke.
//! * A strong ETag derived from the already-CRC-verified footer
//!   ([`ArchiveReader::footer_crc`] + file length) travels on every model
//!   response; clients resume with `If-Range` and a stale validator
//!   falls back to the full body instead of splicing mismatched bytes.
//! * `GET /models/<name>/manifest` exposes the chunk directory as JSON —
//!   everything a client needs to schedule chunk-aligned parallel pulls.
//! * `GET /metrics` renders the process-wide registry as Prometheus text.
//!
//! Robustness is part of the contract: request heads are bounded
//! ([`http::MAX_REQUEST_BYTES`] → `431`) and deadline-guarded (slow-loris
//! → `408`), malformed requests get typed 4xx responses, the connection cap
//! answers `503` instead of queueing without bound, and a client vanishing
//! mid-transfer releases its slot without poisoning the pool.

pub mod http;

use crate::container::{ArchiveReader, ReadAdvice};
use crate::error::{Error, Result};
use crate::exec::WorkerPool;
use crate::obs::{self, Counter, Gauge, Histogram};
use crate::util::jsonout as jo;
use std::collections::BTreeMap;
use std::io::Write;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Bytes handed to the socket per write while streaming a model. Large
/// enough to amortize syscalls, small enough that a disconnect is noticed
/// promptly and pread-backed servers never buffer much per connection.
const STREAM_CHUNK: usize = 256 * 1024;

/// Global-registry handles for server instrumentation, fetched once (the
/// ROADMAP contract: serving reports through [`crate::obs`], it does not
/// invent counters).
struct ServeMetrics {
    /// `serve.requests_model_total` / `_manifest_total` / `_metrics_total`
    /// — requests routed per endpoint.
    model_requests: Arc<Counter>,
    manifest_requests: Arc<Counter>,
    metrics_requests: Arc<Counter>,
    /// `serve.request_model_ns` / `_manifest_ns` / `_metrics_ns` —
    /// per-endpoint latency, first byte read to last byte written.
    model_ns: Arc<Histogram>,
    manifest_ns: Arc<Histogram>,
    metrics_ns: Arc<Histogram>,
    /// `serve.bytes_sent_total` — response body bytes that reached the
    /// socket.
    bytes_sent: Arc<Counter>,
    /// `serve.responses_4xx_total` / `serve.responses_5xx_total` — error
    /// responses by class (including 503 admission rejections).
    responses_4xx: Arc<Counter>,
    responses_5xx: Arc<Counter>,
    /// `serve.rejected_total` — connections answered `503` at the cap.
    rejected: Arc<Counter>,
    /// `serve.disconnects_total` — clients that vanished mid-request or
    /// mid-stream.
    disconnects: Arc<Counter>,
    /// `serve.inflight_connections` — accepted connections currently being
    /// served (gauge with high-water mark).
    inflight: Arc<Gauge>,
}

fn serve_metrics() -> &'static ServeMetrics {
    static METRICS: OnceLock<ServeMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let reg = obs::global();
        ServeMetrics {
            model_requests: reg.counter("serve.requests_model_total"),
            manifest_requests: reg.counter("serve.requests_manifest_total"),
            metrics_requests: reg.counter("serve.requests_metrics_total"),
            model_ns: reg.histogram("serve.request_model_ns"),
            manifest_ns: reg.histogram("serve.request_manifest_ns"),
            metrics_ns: reg.histogram("serve.request_metrics_ns"),
            bytes_sent: reg.counter("serve.bytes_sent_total"),
            responses_4xx: reg.counter("serve.responses_4xx_total"),
            responses_5xx: reg.counter("serve.responses_5xx_total"),
            rejected: reg.counter("serve.rejected_total"),
            disconnects: reg.counter("serve.disconnects_total"),
            inflight: reg.gauge("serve.inflight_connections"),
        }
    })
}

/// Characters allowed in a served model name. One URL path segment, no
/// percent-encoding needed, no traversal: names are registry keys, never
/// filesystem paths at request time.
fn valid_model_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 256
        && !name.starts_with('.')
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b'-'))
}

/// The set of archives a [`serve`] instance distributes, keyed by the name
/// clients request as `/models/<name>`.
///
/// Readers are [`Arc`]-shared across connections: on the mmap backing all
/// concurrent pulls of one model serve out of the same file mapping.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    models: BTreeMap<String, Arc<ArchiveReader>>,
}

impl ModelRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `reader` under `name`. Rejects invalid names (one path
    /// segment of `[A-Za-z0-9._-]`, no leading dot), duplicates, and v1
    /// archives — v1 files are loaded per-tensor and have no byte-
    /// addressable file image to serve ranges from.
    pub fn insert(&mut self, name: &str, reader: ArchiveReader) -> Result<()> {
        if !valid_model_name(name) {
            return Err(Error::InvalidInput(format!("invalid model name '{name}'")));
        }
        if reader.backing_kind() == "memory" {
            return Err(Error::InvalidInput(format!(
                "model '{name}': raw byte serving needs a v2 archive on a file backing"
            )));
        }
        if self.models.contains_key(name) {
            return Err(Error::InvalidInput(format!("duplicate model name '{name}'")));
        }
        self.models.insert(name.to_string(), Arc::new(reader));
        Ok(())
    }

    /// Open every `*.zlp` file directly under `root` (file name = model
    /// name) with the given backing. Strict: a `.zlp` file that fails to
    /// open, or is a v1 archive, fails the whole scan — a distribution
    /// server silently dropping models is worse than one that refuses to
    /// start.
    pub fn open_dir(root: &Path, backing: crate::container::ReadBacking) -> Result<Self> {
        let mut registry = Self::new();
        let mut paths: Vec<_> = std::fs::read_dir(root)?
            .collect::<std::io::Result<Vec<_>>>()?
            .into_iter()
            .map(|e| e.path())
            .filter(|p| p.is_file() && p.extension().is_some_and(|e| e == "zlp"))
            .collect();
        paths.sort();
        for path in paths {
            let name = path
                .file_name()
                .and_then(|n| n.to_str())
                .ok_or_else(|| {
                    Error::InvalidInput(format!("unservable file name: {}", path.display()))
                })?
                .to_string();
            let reader = ArchiveReader::open_with(&path, backing)?;
            registry.insert(&name, reader)?;
        }
        Ok(registry)
    }

    /// Look up a model by name.
    pub fn get(&self, name: &str) -> Option<&Arc<ArchiveReader>> {
        self.models.get(name)
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// True when no models are registered.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

/// Configuration for [`serve`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks an ephemeral port —
    /// read the real one off [`ServerHandle::addr`]).
    pub addr: String,
    /// Concurrent connection handlers (pool helper threads). `0` clamps to
    /// 1. The accept thread itself never serves requests.
    pub workers: usize,
    /// Admission cap: accepted-but-unfinished connections beyond this are
    /// answered `503` immediately instead of queueing without bound. `0`
    /// clamps to 1.
    pub max_conns: usize,
    /// Slow-loris guard: a request head that has not completed within this
    /// budget is answered `408`.
    pub header_timeout: Duration,
    /// Per-write stall guard while streaming a body: a client that stops
    /// reading for longer than this is treated as disconnected, releasing
    /// the worker slot.
    pub write_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            max_conns: 64,
            header_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(30),
        }
    }
}

/// Shared state every connection handler sees.
struct ServeContext {
    registry: ModelRegistry,
    header_timeout: Duration,
    write_timeout: Duration,
}

/// Handle to a running [`serve`] instance. Dropping it stops the server:
/// the accept loop exits, queued connections drain, and worker threads
/// join.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting, drain in-flight connections, join
    /// every thread. Idempotent.
    pub fn stop(&mut self) {
        let Some(handle) = self.accept.take() else {
            return;
        };
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept(2) call with one throwaway connection aimed at
        // the loopback of whatever family we bound.
        let ip: IpAddr = match self.addr {
            SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        };
        let _ = TcpStream::connect_timeout(
            &SocketAddr::new(ip, self.addr.port()),
            Duration::from_secs(1),
        );
        let _ = handle.join();
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

impl std::fmt::Debug for ServerHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerHandle").field("addr", &self.addr).finish()
    }
}

/// Start serving `registry` per `opts`; returns once the listener is bound
/// (requests are handled on background threads from then on).
pub fn serve(registry: ModelRegistry, opts: &ServeOptions) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&opts.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let ctx = Arc::new(ServeContext {
        registry,
        header_timeout: opts.header_timeout,
        write_timeout: opts.write_timeout,
    });
    let accept_stop = Arc::clone(&stop);
    let workers = opts.workers.max(1);
    let max_conns = opts.max_conns.max(1);
    let accept = std::thread::spawn(move || {
        // workers + 1: the accept thread counts as the pool's implicit
        // calling thread but never runs connection jobs, so `workers`
        // helpers do the serving.
        let pool = WorkerPool::new(workers + 1);
        accept_loop(&listener, &pool, &ctx, &accept_stop, max_conns);
        // Pool drop drains any still-queued connections and joins helpers;
        // in-flight responses finish before stop() returns.
    });
    Ok(ServerHandle { addr, stop, accept: Some(accept) })
}

fn accept_loop(
    listener: &TcpListener,
    pool: &WorkerPool,
    ctx: &Arc<ServeContext>,
    stop: &AtomicBool,
    max_conns: usize,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) => {
                // Transient accept failures (EMFILE under load, EINTR) must
                // not kill the server; re-check stop and go around.
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if stop.load(Ordering::SeqCst) {
            return; // the shutdown self-connect (or racing client) — drop it
        }
        if pool.inflight() >= max_conns {
            let m = serve_metrics();
            m.rejected.incr();
            m.responses_5xx.incr();
            reject_busy(stream, ctx.write_timeout);
            continue;
        }
        let ctx = Arc::clone(ctx);
        // The Task handle is dropped deliberately: the job owns everything
        // it needs and its result is (); panics are contained by the pool.
        drop(pool.submit(move || handle_connection(stream, &ctx)));
    }
}

/// Answer `503` on the accept thread without taking a worker slot. Best
/// effort: the head fits any socket send buffer, and a client that cannot
/// take even that is simply dropped.
fn reject_busy(mut stream: TcpStream, write_timeout: Duration) {
    let _ = stream.set_write_timeout(Some(write_timeout));
    let body = "server at connection capacity, retry\n";
    let head = http::response_head(
        503,
        &[
            ("content-type", "text/plain; charset=utf-8".to_string()),
            ("content-length", body.len().to_string()),
            ("retry-after", "1".to_string()),
        ],
    );
    let _ = stream.write_all(head.as_bytes()).and_then(|()| stream.write_all(body.as_bytes()));
}

/// Decrements `serve.inflight_connections` when the handler returns by any
/// path — early error, panic unwinding through the pool's catch, or normal
/// completion.
struct InflightGuard;

impl Drop for InflightGuard {
    fn drop(&mut self) {
        serve_metrics().inflight.sub(1);
    }
}

fn handle_connection(mut stream: TcpStream, ctx: &ServeContext) {
    let _span = crate::span!("serve.request");
    let m = serve_metrics();
    m.inflight.add(1);
    let _guard = InflightGuard;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(ctx.write_timeout));
    let request = match http::read_request(&mut stream, ctx.header_timeout) {
        Ok(request) => request,
        Err(e) => {
            match e.status() {
                Some(status) => {
                    let detail = match e {
                        http::RequestError::Malformed(ref why) => why.clone(),
                        _ => http::status_reason(status).to_string(),
                    };
                    respond_error(&mut stream, status, &detail);
                }
                None => m.disconnects.incr(),
            }
            return;
        }
    };
    route(&mut stream, ctx, &request);
}

/// Write an error response with a one-line plain-text body; counts the
/// response class. Write failures mean the client is gone — counted, not
/// propagated.
fn respond_error(stream: &mut TcpStream, status: u16, detail: &str) {
    let m = serve_metrics();
    if status >= 500 {
        m.responses_5xx.incr();
    } else {
        m.responses_4xx.incr();
    }
    let body = format!("{} {}: {detail}\n", status, http::status_reason(status));
    let mut headers = vec![
        ("content-type", "text/plain; charset=utf-8".to_string()),
        ("content-length", body.len().to_string()),
    ];
    if status == 405 {
        headers.push(("allow", "GET, HEAD".to_string()));
    }
    let head = http::response_head(status, &headers);
    if stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body.as_bytes()))
        .is_err()
    {
        m.disconnects.incr();
    }
}

fn route(stream: &mut TcpStream, ctx: &ServeContext, request: &http::Request) {
    let m = serve_metrics();
    let head_only = match request.method.as_str() {
        "GET" => false,
        "HEAD" => true,
        other => {
            respond_error(stream, 405, &format!("method '{other}' not supported"));
            return;
        }
    };
    let start = Instant::now();
    let target = request.target.as_str();
    if target == "/metrics" {
        m.metrics_requests.incr();
        serve_metrics_page(stream, head_only);
        m.metrics_ns.record(elapsed_ns(start));
        return;
    }
    if target == "/models" {
        m.manifest_requests.incr();
        serve_model_list(stream, ctx, head_only);
        m.manifest_ns.record(elapsed_ns(start));
        return;
    }
    if let Some(rest) = target.strip_prefix("/models/") {
        if let Some(name) = rest.strip_suffix("/manifest") {
            m.manifest_requests.incr();
            serve_manifest(stream, ctx, name, head_only);
            m.manifest_ns.record(elapsed_ns(start));
            return;
        }
        m.model_requests.incr();
        serve_model(stream, ctx, rest, request, head_only);
        m.model_ns.record(elapsed_ns(start));
        return;
    }
    respond_error(stream, 404, &format!("no route for '{target}'"));
}

fn elapsed_ns(start: Instant) -> u64 {
    u64::try_from(start.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

/// Strong ETag for an archive: footer CRC (verified at open) + file length
/// identify the exact bytes on disk, so a resumed pull can trust
/// `If-Range` matches against it.
fn model_etag(reader: &ArchiveReader) -> String {
    format!("\"zlps-{:08x}-{:x}\"", reader.footer_crc(), reader.file_len())
}

/// Write a fully-buffered response (manifest JSON, metrics text).
fn respond_body(stream: &mut TcpStream, content_type: &str, body: &[u8], head_only: bool) {
    let m = serve_metrics();
    let head = http::response_head(
        200,
        &[
            ("content-type", content_type.to_string()),
            ("content-length", body.len().to_string()),
        ],
    );
    let result = stream.write_all(head.as_bytes()).and_then(|()| {
        if head_only {
            return Ok(());
        }
        stream.write_all(body)?;
        m.bytes_sent.add(body.len() as u64);
        Ok(())
    });
    if result.is_err() {
        m.disconnects.incr();
    }
}

fn serve_metrics_page(stream: &mut TcpStream, head_only: bool) {
    let text = obs::export::prometheus_text(&obs::global().snapshot());
    respond_body(stream, "text/plain; charset=utf-8", text.as_bytes(), head_only);
}

fn serve_model_list(stream: &mut TcpStream, ctx: &ServeContext, head_only: bool) {
    let rows: Vec<String> = ctx
        .registry
        .names()
        .iter()
        .map(|name| {
            let reader = ctx.registry.get(name).expect("listed name resolves");
            jo::obj(&[
                ("name", jo::string(name)),
                ("file_len", jo::uint(reader.file_len())),
                ("etag", jo::string(&model_etag(reader))),
                ("tensors", jo::uint(reader.len() as u64)),
            ])
        })
        .collect();
    let body = jo::obj(&[("models", jo::arr(&rows))]);
    respond_body(stream, "application/json", body.as_bytes(), head_only);
}

/// The chunk directory as JSON: per tensor, where its encoded chunks live
/// in the file and what they decode to — enough for a client to schedule
/// chunk-aligned parallel range pulls and to know the decoded layout.
fn serve_manifest(stream: &mut TcpStream, ctx: &ServeContext, name: &str, head_only: bool) {
    let Some(reader) = ctx.registry.get(name) else {
        respond_error(stream, 404, &format!("no model '{name}'"));
        return;
    };
    let tensors: Vec<String> = reader
        .entries()
        .map(|e| {
            let shape: Vec<String> = e.meta.shape.iter().map(|&d| jo::uint(d)).collect();
            jo::obj(&[
                ("name", jo::string(&e.meta.name)),
                ("shape", jo::arr(&shape)),
                ("format", jo::string(e.format.name())),
                ("codec", jo::string(e.codec.name())),
                ("strategy", jo::string(e.strategy.name())),
                ("original_len", jo::uint(e.original_len as u64)),
                ("chunk_size", jo::uint(e.chunk_size as u64)),
                ("data_offset", jo::uint(e.data_offset)),
                ("data_len", jo::uint(e.data_len())),
                ("n_chunks", jo::uint(e.chunks.len() as u64)),
            ])
        })
        .collect();
    let body = jo::obj(&[
        ("name", jo::string(name)),
        ("etag", jo::string(&model_etag(reader))),
        ("file_len", jo::uint(reader.file_len())),
        ("footer_crc", jo::uint(u64::from(reader.footer_crc()))),
        ("version", jo::uint(u64::from(reader.version()))),
        ("backing", jo::string(reader.backing_kind())),
        ("total_original", jo::uint(reader.total_original())),
        ("total_encoded", jo::uint(reader.total_encoded())),
        ("tensors", jo::arr(&tensors)),
    ]);
    respond_body(stream, "application/json", body.as_bytes(), head_only);
}

/// Stream archive bytes: `200` whole-file, `206` single range, `416`
/// unsatisfiable — with `If-Range` downgrading a stale resume to the full
/// body.
fn serve_model(
    stream: &mut TcpStream,
    ctx: &ServeContext,
    name: &str,
    request: &http::Request,
    head_only: bool,
) {
    let m = serve_metrics();
    let Some(reader) = ctx.registry.get(name) else {
        respond_error(stream, 404, &format!("no model '{name}'"));
        return;
    };
    let total = reader.file_len();
    let etag = model_etag(reader);
    let mut range = match request.header("range") {
        Some(value) => http::parse_range(value, total),
        None => http::RangeSpec::Whole,
    };
    if !matches!(range, http::RangeSpec::Whole) {
        if let Some(validator) = request.header("if-range") {
            if validator != etag {
                // The client's partial copy is of different bytes; splicing
                // a range onto it would corrupt the pull. Full body instead.
                range = http::RangeSpec::Whole;
            }
        }
    }
    let (status, start, len) = match range {
        http::RangeSpec::Unsatisfiable => {
            m.responses_4xx.incr();
            let head = http::response_head(
                416,
                &[
                    ("content-range", format!("bytes */{total}")),
                    ("content-length", "0".to_string()),
                    ("etag", etag),
                ],
            );
            if stream.write_all(head.as_bytes()).is_err() {
                m.disconnects.incr();
            }
            return;
        }
        http::RangeSpec::Whole => (200, 0u64, total),
        http::RangeSpec::Single { start, len } => (206, start, len),
    };
    let mut headers = vec![
        ("content-type", "application/octet-stream".to_string()),
        ("content-length", len.to_string()),
        ("accept-ranges", "bytes".to_string()),
        ("etag", etag),
    ];
    if status == 206 {
        headers.push(("content-range", format!("bytes {start}-{}/{total}", start + len - 1)));
    }
    let head = http::response_head(status, &headers);
    if stream.write_all(head.as_bytes()).is_err() {
        m.disconnects.incr();
        return;
    }
    if head_only || len == 0 {
        return;
    }
    // The whole response region is about to be read front-to-back: tell the
    // kernel (mmap backing) to read it ahead instead of faulting per chunk.
    reader.advise(start, len as usize, ReadAdvice::Sequential);
    let mut offset = start;
    let end = start + len;
    while offset < end {
        let step = STREAM_CHUNK.min((end - offset) as usize);
        let bytes = match reader.read_file_range(offset, step) {
            Ok(bytes) => bytes,
            Err(_) => {
                // The range was validated against file_len, so this is the
                // storage failing underneath us mid-response. The head is
                // already on the wire: all we can do is stop short, which
                // the client detects as a content-length mismatch.
                m.responses_5xx.incr();
                return;
            }
        };
        if stream.write_all(&bytes).is_err() {
            // Client went away (or stalled past the write timeout): release
            // the slot quietly. This must never unwind — a disconnect is
            // routine, not a pool-poisoning event.
            m.disconnects.incr();
            return;
        }
        m.bytes_sent.add(step as u64);
        offset += step as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{compress_tensor, CompressOptions};
    use crate::container::{Archive, ReadBacking, TensorMeta};
    use crate::formats::FloatFormat;
    use crate::synthetic;
    use std::io::Read;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("zipnn_lp_test_serve")
            .join(format!("{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_archive(path: &Path, seed: u64) -> Vec<u8> {
        let mut archive = Archive::new();
        for (i, name) in ["wq", "wk"].iter().enumerate() {
            let data = synthetic::gaussian_bf16_bytes(2000 + i * 256, 0.02, seed + i as u64);
            let blob =
                compress_tensor(&data, &CompressOptions::for_format(FloatFormat::Bf16)).unwrap();
            let meta = TensorMeta { name: name.to_string(), shape: vec![data.len() as u64 / 2] };
            archive.insert(meta, blob);
        }
        archive.save(path).unwrap();
        std::fs::read(path).unwrap()
    }

    /// One request, whole response (head + body) as raw bytes.
    fn raw_request(addr: SocketAddr, request: &str) -> Vec<u8> {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(request.as_bytes()).unwrap();
        let mut out = Vec::new();
        stream.read_to_end(&mut out).unwrap();
        out
    }

    fn body_of(response: &[u8]) -> &[u8] {
        let pos = response.windows(4).position(|w| w == b"\r\n\r\n").expect("head terminator");
        &response[pos + 4..]
    }

    fn status_of(response: &[u8]) -> u16 {
        let line = std::str::from_utf8(&response[..response.len().min(64)]).unwrap();
        line.split(' ').nth(1).unwrap().parse().unwrap()
    }

    #[test]
    fn registry_validates_names_and_backings() {
        let dir = tmpdir("registry");
        let path = dir.join("m.zlp");
        write_archive(&path, 1);
        let mut registry = ModelRegistry::new();
        let open = || ArchiveReader::open(&path).unwrap();
        for bad in ["", "a/b", "../m", ".hidden", "na me", "x\u{e9}"] {
            assert!(registry.insert(bad, open()).is_err(), "accepted name {bad:?}");
        }
        registry.insert("m.zlp", open()).unwrap();
        assert!(registry.insert("m.zlp", open()).is_err(), "duplicate accepted");
        // v1 archives (memory backing) are rejected.
        let v1_path = dir.join("v1.bin");
        let mut v1 = Archive::new();
        let data = synthetic::gaussian_bf16_bytes(500, 0.02, 9);
        let blob =
            compress_tensor(&data, &CompressOptions::for_format(FloatFormat::Bf16)).unwrap();
        v1.insert(TensorMeta { name: "t".into(), shape: vec![500] }, blob);
        std::fs::write(&v1_path, v1.serialize()).unwrap();
        let v1_reader = ArchiveReader::open(&v1_path).unwrap();
        assert!(registry.insert("v1", v1_reader).is_err(), "v1 accepted");
        assert_eq!(registry.names(), vec!["m.zlp".to_string()]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_dir_scans_zlp_files_only() {
        let dir = tmpdir("open_dir");
        write_archive(&dir.join("a.zlp"), 2);
        write_archive(&dir.join("b.zlp"), 3);
        std::fs::write(dir.join("notes.txt"), b"ignored").unwrap();
        let registry = ModelRegistry::open_dir(&dir, ReadBacking::Auto).unwrap();
        assert_eq!(registry.names(), vec!["a.zlp".to_string(), "b.zlp".to_string()]);
        assert_eq!(registry.len(), 2);
        // A corrupt .zlp fails the whole scan.
        std::fs::write(dir.join("junk.zlp"), b"not an archive").unwrap();
        assert!(ModelRegistry::open_dir(&dir, ReadBacking::Auto).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serves_full_ranged_and_metrics_over_loopback() {
        let dir = tmpdir("e2e");
        let file = write_archive(&dir.join("m.zlp"), 4);
        let registry = ModelRegistry::open_dir(&dir, ReadBacking::Auto).unwrap();
        let opts = ServeOptions { workers: 2, ..ServeOptions::default() };
        let mut server = serve(registry, &opts).unwrap();
        let addr = server.addr();

        // Full pull is bit-exact.
        let full = raw_request(addr, "GET /models/m.zlp HTTP/1.1\r\nhost: t\r\n\r\n");
        assert_eq!(status_of(&full), 200);
        assert_eq!(body_of(&full), &file[..]);
        // Ranged pull returns exactly the slice, 206.
        let ranged = raw_request(
            addr,
            "GET /models/m.zlp HTTP/1.1\r\nhost: t\r\nrange: bytes=10-49\r\n\r\n",
        );
        assert_eq!(status_of(&ranged), 206);
        assert_eq!(body_of(&ranged), &file[10..50]);
        // Unknown model 404s; unknown route 404s; POST 405s.
        assert_eq!(
            status_of(&raw_request(addr, "GET /models/nope HTTP/1.1\r\n\r\n")),
            404
        );
        assert_eq!(status_of(&raw_request(addr, "GET /elsewhere HTTP/1.1\r\n\r\n")), 404);
        assert_eq!(status_of(&raw_request(addr, "POST /models/m.zlp HTTP/1.1\r\n\r\n")), 405);
        // Metrics endpoint renders the registry (our own counters included).
        let metrics = raw_request(addr, "GET /metrics HTTP/1.1\r\n\r\n");
        assert_eq!(status_of(&metrics), 200);
        let text = String::from_utf8(body_of(&metrics).to_vec()).unwrap();
        assert!(text.contains("serve_requests_model_total"), "metrics body:\n{text}");
        server.stop();
        // Idempotent stop, and the port is released for rebinding.
        server.stop();
        std::fs::remove_dir_all(&dir).ok();
    }
}
