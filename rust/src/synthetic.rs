//! Synthetic neural-network workload generators.
//!
//! These replay the *distributional* properties the paper's compression
//! exploits — near-Gaussian weights whose exponents concentrate on a few
//! values, converging checkpoint trajectories, transformer-shaped tensor
//! manifests — at any scale, so the model-zoo experiments (Fig 8, Fig 9)
//! run on this machine. See DESIGN.md §4 for the substitution argument.
//!
//! Everything is seeded and bit-reproducible.

use crate::formats::conv::{f32_to_bf16, quantize_slice};
use crate::formats::FloatFormat;
use crate::util::rng::Rng;

/// Gaussian f32 samples, mean 0, std `std`.
pub fn gaussian_f32(n: usize, std: f64, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_ms(0.0, std) as f32).collect()
}

/// Gaussian weights quantized to little-endian BF16 bytes.
pub fn gaussian_bf16_bytes(n: usize, std: f64, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let v = rng.normal_ms(0.0, std) as f32;
        out.extend_from_slice(&f32_to_bf16(v).to_le_bytes());
    }
    out
}

/// Perturb a BF16 byte buffer like one optimizer step: with probability
/// `p_change`, add N(0, rel_std·|w|+1e-8) to the weight. Models the
/// "converging fine-tune" that makes XOR deltas sparse (§3.1).
pub fn perturb_bf16_bytes(base: &[u8], rel_std: f64, p_change: f64, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(base.len());
    for pair in base.chunks_exact(2) {
        let w = u16::from_le_bytes([pair[0], pair[1]]);
        let v = crate::formats::conv::bf16_to_f32(w);
        let nv = if rng.next_f64() < p_change {
            let scale = (v.abs() as f64) * rel_std + 1e-8;
            v + rng.normal_ms(0.0, scale) as f32
        } else {
            v
        };
        out.extend_from_slice(&f32_to_bf16(nv).to_le_bytes());
    }
    out
}

/// One named tensor of a synthetic model manifest.
#[derive(Clone, Debug)]
pub struct SyntheticTensor {
    /// Layer-qualified name (`layers.3.attn.wq` …).
    pub name: String,
    /// Element count.
    pub n_elements: usize,
    /// Per-tensor weight std (layer-dependent, like real inits).
    pub std: f64,
}

/// A transformer-shaped model manifest: the tensor list of a GPT-style
/// model with `layers` blocks of width `d_model`, as real checkpoints have.
pub fn transformer_manifest(d_model: usize, layers: usize, vocab: usize) -> Vec<SyntheticTensor> {
    let mut ts = Vec::new();
    let d = d_model;
    ts.push(SyntheticTensor {
        name: "tok_embeddings.weight".into(),
        n_elements: vocab * d,
        std: 0.02,
    });
    for l in 0..layers {
        // Attention projections: Xavier-ish std 1/sqrt(d).
        let attn_std = 1.0 / (d as f64).sqrt();
        for proj in ["wq", "wk", "wv", "wo"] {
            ts.push(SyntheticTensor {
                name: format!("layers.{l}.attention.{proj}.weight"),
                n_elements: d * d,
                std: attn_std,
            });
        }
        // MLP: 4× expansion; second projection scaled down with depth.
        ts.push(SyntheticTensor {
            name: format!("layers.{l}.feed_forward.w1.weight"),
            n_elements: d * 4 * d,
            std: attn_std,
        });
        ts.push(SyntheticTensor {
            name: format!("layers.{l}.feed_forward.w2.weight"),
            n_elements: 4 * d * d,
            std: attn_std / (2.0 * (l + 1) as f64).sqrt(),
        });
        // LayerNorm gains: near 1.0, tiny variance — very compressible.
        ts.push(SyntheticTensor {
            name: format!("layers.{l}.attention_norm.weight"),
            n_elements: d,
            std: 0.01,
        });
        ts.push(SyntheticTensor {
            name: format!("layers.{l}.ffn_norm.weight"),
            n_elements: d,
            std: 0.01,
        });
    }
    ts.push(SyntheticTensor { name: "norm.weight".into(), n_elements: d, std: 0.01 });
    ts.push(SyntheticTensor { name: "output.weight".into(), n_elements: vocab * d, std: 0.02 });
    ts
}

/// Materialize one manifest tensor's values. LayerNorm-ish tensors
/// (name contains "norm") center at 1.0, everything else at 0.
pub fn materialize(t: &SyntheticTensor, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ fnv1a(&t.name));
    let mean = if t.name.contains("norm") { 1.0 } else { 0.0 };
    (0..t.n_elements).map(|_| rng.normal_ms(mean, t.std) as f32).collect()
}

/// Materialize and quantize a manifest tensor to `format` bytes.
pub fn materialize_bytes(t: &SyntheticTensor, format: FloatFormat, seed: u64) -> Vec<u8> {
    let vals = materialize(t, seed);
    quantize_slice(&vals, format).expect("quantize")
}

/// Synthetic K/V-cache-like tensor: attention keys/values have per-channel
/// structure (RMS-normalized activations → exponents cluster) plus a few
/// high-magnitude outlier channels, matching published K/V statistics.
pub fn kv_cache_f32(n_tokens: usize, head_dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    // Per-channel scales: log-normal, a few outliers.
    let scales: Vec<f64> = (0..head_dim)
        .map(|_| {
            let base = (rng.normal_ms(0.0, 0.6)).exp() * 0.3;
            if rng.next_f64() < 0.03 {
                base * 8.0
            } else {
                base
            }
        })
        .collect();
    let mut out = Vec::with_capacity(n_tokens * head_dim);
    for _t in 0..n_tokens {
        for c in 0..head_dim {
            out.push(rng.normal_ms(0.0, scales[c]) as f32);
        }
    }
    out
}

/// One token's quantized K+V bytes (exactly `2 * bytes_per_token`) for a
/// cache config, drawn from [`kv_cache_f32`] — the shared generator behind
/// the pool tests, the pool property tests, and the `kv_cache` bench, so
/// they cannot drift from each other or from the config's geometry.
/// Panics on formats without a whole byte width (the K/V cache rejects
/// those at construction anyway).
pub fn kv_token_bytes(config: &crate::kvcache::KvCacheConfig, seed: u64) -> Vec<u8> {
    let elem = config
        .format
        .byte_width()
        .expect("K/V cache formats have a whole byte width");
    let n = 2 * config.bytes_per_token / elem;
    let vals = kv_cache_f32(1, n, seed);
    quantize_slice(&vals, config.format).expect("K/V cache format is quantizable")
}

/// FNV-1a hash for stable per-name seeds.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Total parameter count of a manifest.
pub fn manifest_params(ts: &[SyntheticTensor]) -> usize {
    ts.iter().map(|t| t.n_elements).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::Histogram;
    use crate::formats::split_streams;

    #[test]
    fn gaussian_bf16_exponents_are_skewed() {
        let data = gaussian_bf16_bytes(20_000, 0.02, 1);
        let set = split_streams(FloatFormat::Bf16, &data).unwrap();
        let h = Histogram::from_bytes(&set.exponent().unwrap().bytes);
        assert!(h.entropy_bits() < 4.0, "H={}", h.entropy_bits());
    }

    #[test]
    fn perturb_changes_subset() {
        let base = gaussian_bf16_bytes(10_000, 0.02, 2);
        let p = perturb_bf16_bytes(&base, 0.01, 0.3, 3);
        assert_eq!(p.len(), base.len());
        let changed = base
            .chunks_exact(2)
            .zip(p.chunks_exact(2))
            .filter(|(a, b)| a != b)
            .count();
        // ~30% of elements change (quantization may hide tiny deltas).
        assert!(changed > 1_000 && changed < 4_000, "changed={changed}");
    }

    #[test]
    fn perturb_is_deterministic() {
        let base = gaussian_bf16_bytes(1_000, 0.02, 4);
        assert_eq!(
            perturb_bf16_bytes(&base, 0.01, 0.5, 5),
            perturb_bf16_bytes(&base, 0.01, 0.5, 5)
        );
    }

    #[test]
    fn manifest_shape() {
        let m = transformer_manifest(256, 4, 1024);
        let params = manifest_params(&m);
        assert!(params > 2 * 1024 * 256);
        assert!(m.iter().any(|t| t.name.contains("attention.wq")));
        assert!(m.iter().any(|t| t.name.contains("norm")));
    }

    #[test]
    fn materialize_stable_per_name() {
        let m = transformer_manifest(64, 1, 128);
        let a = materialize(&m[0], 7);
        let b = materialize(&m[0], 7);
        assert_eq!(a, b);
        let c = materialize(&m[1], 7);
        assert_ne!(a[..8], c[..8]);
    }

    #[test]
    fn norm_tensors_center_at_one() {
        let m = transformer_manifest(512, 1, 64);
        let norm = m.iter().find(|t| t.name.contains("attention_norm")).unwrap();
        let vals = materialize(norm, 9);
        let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn kv_cache_has_outlier_channels() {
        let kv = kv_cache_f32(256, 64, 11);
        assert_eq!(kv.len(), 256 * 64);
        let mut rms = vec![0f64; 64];
        for t in 0..256 {
            for c in 0..64 {
                rms[c] += (kv[t * 64 + c] as f64).powi(2);
            }
        }
        let rms: Vec<f64> = rms.iter().map(|s| (s / 256.0).sqrt()).collect();
        let max = rms.iter().cloned().fold(0.0, f64::max);
        let min = rms.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 3.0, "spread {}", max / min);
    }
}
