//! CRC-32 (IEEE 802.3 polynomial, reflected) — the integrity check used by
//! every chunk in the `zlp` container format.
//!
//! Implementation: slice-by-8 table lookup. On one core this sustains
//! ~3 GB/s, comfortably above codec throughput, so integrity checking never
//! becomes the bottleneck (measured in `benches/codec_throughput.rs`).

/// Reflected polynomial for CRC-32/IEEE (same as zlib, PNG, Ethernet).
const POLY: u32 = 0xEDB8_8320;

/// 8 tables × 256 entries for slice-by-8.
static TABLES: [[u32; 256]; 8] = build_tables();

const fn build_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut b = 0;
        while b < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            b += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    t
}

/// Streaming CRC-32 hasher.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Create a fresh hasher.
    pub fn new() -> Self {
        Crc32 { state: !0 }
    }

    /// Feed `data` into the hasher.
    pub fn update(&mut self, data: &[u8]) {
        let mut crc = self.state;
        let mut chunks = data.chunks_exact(8);
        for c in &mut chunks {
            let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ crc;
            let hi = u32::from_le_bytes([c[4], c[5], c[6], c[7]]);
            crc = TABLES[7][(lo & 0xFF) as usize]
                ^ TABLES[6][((lo >> 8) & 0xFF) as usize]
                ^ TABLES[5][((lo >> 16) & 0xFF) as usize]
                ^ TABLES[4][(lo >> 24) as usize]
                ^ TABLES[3][(hi & 0xFF) as usize]
                ^ TABLES[2][((hi >> 8) & 0xFF) as usize]
                ^ TABLES[1][((hi >> 16) & 0xFF) as usize]
                ^ TABLES[0][(hi >> 24) as usize];
        }
        for &b in chunks.remainder() {
            crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    /// Finish and return the checksum.
    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference vectors from the CRC catalogue (CRC-32/ISO-HDLC).
    #[test]
    fn known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
        assert_eq!(crc32(&[0xFFu8; 32]), 0xFF6C_AB0B);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7 + 13) as u8).collect();
        for split in [0, 1, 7, 8, 9, 500, 999, 1000] {
            let mut h = Crc32::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), crc32(&data), "split at {split}");
        }
    }

    #[test]
    fn bitflip_changes_crc() {
        let mut data = vec![0x5Au8; 100];
        let base = crc32(&data);
        data[57] ^= 0x04;
        assert_ne!(base, crc32(&data));
    }

    // Slice-by-8 path vs bytewise path must agree on every alignment.
    #[test]
    fn alignment_independence() {
        let data: Vec<u8> = (0..257u32).map(|i| (i ^ (i >> 3)) as u8).collect();
        let bytewise = {
            let mut crc: u32 = !0;
            for &b in &data {
                crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
            }
            !crc
        };
        assert_eq!(crc32(&data), bytewise);
    }
}
