//! Minimal JSON parser for the AOT `manifest.json`.
//!
//! Supports the full JSON value grammar minus exotic number forms; no
//! external dependency (the baked registry has no serde). Parsing is
//! recursive-descent over a byte cursor.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true / false
    Bool(bool),
    /// Any number (stored as f64; manifest integers are < 2^53).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (order-insensitive).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(Error::Corrupt(format!("trailing JSON at byte {pos}")));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with context.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Corrupt(format!("missing JSON field '{key}'")))
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as usize (errors on fraction/negative via None).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(Error::Corrupt(format!(
            "expected '{}' at byte {pos:?}",
            c as char
        )))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(Error::Corrupt("unexpected end of JSON".into())),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(Error::Corrupt(format!("bad literal at byte {pos:?}")))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| Error::Corrupt(format!("bad number at byte {start}")))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(Error::Corrupt("unterminated string".into())),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| Error::Corrupt("bad \\u escape".into()))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex)
                                .map_err(|_| Error::Corrupt("bad \\u escape".into()))?,
                            16,
                        )
                        .map_err(|_| Error::Corrupt("bad \\u escape".into()))?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    _ => return Err(Error::Corrupt("bad escape".into())),
                }
                *pos += 1;
            }
            Some(&c) => {
                // Multi-byte UTF-8 passes through unchanged.
                let len = utf8_len(c);
                let chunk = b
                    .get(*pos..*pos + len)
                    .ok_or_else(|| Error::Corrupt("truncated UTF-8".into()))?;
                out.push_str(
                    std::str::from_utf8(chunk)
                        .map_err(|_| Error::Corrupt("invalid UTF-8".into()))?,
                );
                *pos += len;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(Error::Corrupt(format!("bad array at byte {pos:?}"))),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(Error::Corrupt(format!("bad object at byte {pos:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let doc = r#"{
            "config": {"vocab": 512, "d_model": 128},
            "weight_names": ["embed", "layers.0.wq"],
            "artifacts": {
                "prefill": {
                    "file": "prefill.hlo.txt",
                    "inputs": [{"name": "tokens", "dtype": "int32", "shape": [4, 64]}],
                    "outputs": [{"dtype": "float32", "shape": []}]
                }
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.field("config").unwrap().field("vocab").unwrap().as_usize(), Some(512));
        let names = j.field("weight_names").unwrap().as_arr().unwrap();
        assert_eq!(names[1].as_str(), Some("layers.0.wq"));
        let art = j.field("artifacts").unwrap().field("prefill").unwrap();
        let inp = &art.field("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.field("dtype").unwrap().as_str(), Some("int32"));
        assert_eq!(
            inp.field("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(64)
        );
    }

    #[test]
    fn scalars_and_specials() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nbA""#).unwrap(), Json::Str("a\nbA".into()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"[[1,2],[3,[4,{"x":[5]}]]]"#).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let inner = arr[1].as_arr().unwrap()[1].as_arr().unwrap();
        assert_eq!(inner[1].field("x").unwrap().as_arr().unwrap()[0].as_f64(), Some(5.0));
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo → 世界"));
    }
}
