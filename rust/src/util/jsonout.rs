//! Minimal JSON *emitter* for machine-readable bench artifacts
//! (`BENCH_codec.json`, `BENCH_kv.json`), the writing counterpart of
//! [`super::json`]. Values are pre-rendered JSON fragments built with the
//! typed helpers, so composition is plain string assembly with escaping
//! handled exactly once, in [`string`].

/// Render a JSON string literal with escaping.
pub fn string(v: &str) -> String {
    let mut out = String::with_capacity(v.len() + 2);
    out.push('"');
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render a finite float (non-finite values become `null`, which JSON
/// requires — `NaN` is not valid JSON).
pub fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Render an unsigned integer.
pub fn uint(v: u64) -> String {
    v.to_string()
}

/// Render an object from `(key, pre-rendered value)` pairs.
///
/// Keys are emitted in sorted (byte-lexicographic) order regardless of the
/// order the caller lists them, so every document built here — `analyze
/// --json`, `BENCH_*.json`, metric snapshots — is byte-diffable across
/// runs and across call sites that assemble fields differently.
pub fn obj(fields: &[(&str, String)]) -> String {
    let mut body: Vec<(&str, String)> =
        fields.iter().map(|(k, v)| (*k, format!("{}: {v}", string(k)))).collect();
    body.sort_by(|a, b| a.0.cmp(b.0));
    let body: Vec<String> = body.into_iter().map(|(_, rendered)| rendered).collect();
    format!("{{{}}}", body.join(", "))
}

/// Render an array of pre-rendered values.
pub fn arr(items: &[String]) -> String {
    format!("[{}]", items.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn output_parses_with_the_inhouse_parser() {
        let doc = obj(&[
            ("schema", uint(1)),
            ("name", string("codec \"throughput\"\n")),
            ("ratio", num(0.3125)),
            ("rows", arr(&[obj(&[("x", num(1.0))]), obj(&[("x", num(f64::NAN))])])),
        ]);
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.field("schema").unwrap().as_usize(), Some(1));
        assert_eq!(j.field("name").unwrap().as_str(), Some("codec \"throughput\"\n"));
        assert_eq!(j.field("ratio").unwrap().as_f64(), Some(0.3125));
        let rows = j.field("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[1].field("x").unwrap(), &Json::Null);
    }

    #[test]
    fn object_keys_emit_in_sorted_order() {
        // Byte-diffability contract: the same fields in any declaration
        // order must render to the identical document.
        let a = obj(&[("zeta", uint(1)), ("alpha", uint(2)), ("mid", string("x"))]);
        let b = obj(&[("mid", string("x")), ("zeta", uint(1)), ("alpha", uint(2))]);
        assert_eq!(a, b);
        assert_eq!(a, r#"{"alpha": 2, "mid": "x", "zeta": 1}"#);
        // Nested objects sort independently of their parents.
        let nested = obj(&[("outer_b", a.clone()), ("outer_a", uint(0))]);
        assert!(nested.starts_with(r#"{"outer_a": 0, "outer_b": {"alpha""#));
        let j = Json::parse(&nested).unwrap();
        assert_eq!(
            j.field("outer_b").unwrap().field("zeta").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn control_chars_escaped() {
        let s = string("a\u{1}b");
        assert_eq!(s, "\"a\\u0001b\"");
        assert!(Json::parse(&s).is_ok());
    }
}
