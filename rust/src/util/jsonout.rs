//! Minimal JSON *emitter* for machine-readable bench artifacts
//! (`BENCH_codec.json`, `BENCH_kv.json`), the writing counterpart of
//! [`super::json`]. Values are pre-rendered JSON fragments built with the
//! typed helpers, so composition is plain string assembly with escaping
//! handled exactly once, in [`string`].

/// Render a JSON string literal with escaping.
pub fn string(v: &str) -> String {
    let mut out = String::with_capacity(v.len() + 2);
    out.push('"');
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render a finite float (non-finite values become `null`, which JSON
/// requires — `NaN` is not valid JSON).
pub fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Render an unsigned integer.
pub fn uint(v: u64) -> String {
    v.to_string()
}

/// Render an object from `(key, pre-rendered value)` pairs.
pub fn obj(fields: &[(&str, String)]) -> String {
    let body: Vec<String> =
        fields.iter().map(|(k, v)| format!("{}: {v}", string(k))).collect();
    format!("{{{}}}", body.join(", "))
}

/// Render an array of pre-rendered values.
pub fn arr(items: &[String]) -> String {
    format!("[{}]", items.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn output_parses_with_the_inhouse_parser() {
        let doc = obj(&[
            ("schema", uint(1)),
            ("name", string("codec \"throughput\"\n")),
            ("ratio", num(0.3125)),
            ("rows", arr(&[obj(&[("x", num(1.0))]), obj(&[("x", num(f64::NAN))])])),
        ]);
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.field("schema").unwrap().as_usize(), Some(1));
        assert_eq!(j.field("name").unwrap().as_str(), Some("codec \"throughput\"\n"));
        assert_eq!(j.field("ratio").unwrap().as_f64(), Some(0.3125));
        let rows = j.field("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[1].field("x").unwrap(), &Json::Null);
    }

    #[test]
    fn control_chars_escaped() {
        let s = string("a\u{1}b");
        assert_eq!(s, "\"a\\u0001b\"");
        assert!(Json::parse(&s).is_ok());
    }
}
