//! Small shared utilities: deterministic PRNG, CRC32, varint encoding,
//! minimal JSON, human-readable byte formatting.
//!
//! The crate deliberately implements these in-house rather than pulling
//! dependencies: reproducibility of the paper's experiments requires a
//! *seeded, stable* random source, and the container format freezes the
//! CRC32 polynomial as part of its spec. Submodules:
//!
//! * [`rng`] — xoshiro256** seeded via SplitMix64; every synthetic
//!   workload in the benches and tests replays bit-exactly from a `u64`.
//! * [`crc32`] — CRC-32/ISO-HDLC with a slice-by-8 kernel; the per-chunk
//!   integrity check of the `zlp` container.
//! * [`varint`] — LEB128-style unsigned varints for container metadata.
//! * [`json`] — recursive-descent JSON used by the AOT manifest reader and
//!   the safetensors header parser.
//! * [`jsonout`] — the matching JSON emitter, used by the benches'
//!   `--json` machine-readable outputs.

pub mod crc32;
pub mod json;
pub mod jsonout;
pub mod rng;
pub mod varint;

/// Format a byte count as a human-readable string (binary units).
///
/// ```
/// assert_eq!(zipnn_lp::util::human_bytes(1536), "1.50 KiB");
/// ```
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    format!("{v:.2} {}", UNITS[unit])
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(1023), "1023 B");
        assert_eq!(human_bytes(1024), "1.00 KiB");
        assert_eq!(human_bytes(1024 * 1024), "1.00 MiB");
        assert_eq!(human_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }
}
