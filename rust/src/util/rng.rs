//! Deterministic pseudo-random number generation.
//!
//! All synthetic-workload generators in this crate are seeded with explicit
//! `u64` seeds so every experiment in EXPERIMENTS.md is bit-reproducible.
//! The generator is xoshiro256**, which has excellent statistical quality
//! for simulation purposes and no external dependency.

/// xoshiro256** by Blackman & Vigna (public domain reference).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that any `u64` (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// simulation; exact rejection for small `n`).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // widening multiply trick
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — generators here are not on any hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Sample an index from a discrete distribution given by `weights`
    /// (need not be normalized). Used by synthetic exponent-histogram replay.
    pub fn discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if x < w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_f64_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(1234);
        let n = 50_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn discrete_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4_000 {
            counts[r.discrete(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn fill_bytes_covers_remainder() {
        let mut r = Rng::new(3);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        // Astronomically unlikely to stay zero.
        assert!(buf.iter().any(|&b| b != 0));
    }
}
