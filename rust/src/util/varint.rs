//! LEB128-style unsigned varint encoding used by container metadata.
//!
//! Chunk metadata is small but numerous (one record per 256 KiB chunk); the
//! varint keeps per-chunk overhead to a few bytes, which matters for the
//! paper's "lightweight metadata stored per block" requirement (§3.1).

use crate::error::{Error, Result};

/// Append `value` to `out` as a varint. Returns the number of bytes written.
pub fn write_u64(out: &mut Vec<u8>, mut value: u64) -> usize {
    let mut n = 0;
    loop {
        let byte = (value & 0x7F) as u8;
        value >>= 7;
        n += 1;
        if value == 0 {
            out.push(byte);
            return n;
        }
        out.push(byte | 0x80);
    }
}

/// Read a varint from `buf` starting at `*pos`, advancing `*pos`.
pub fn read_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut value: u64 = 0;
    let mut shift = 0u32;
    loop {
        let byte = *buf
            .get(*pos)
            .ok_or_else(|| Error::Corrupt("varint truncated".into()))?;
        *pos += 1;
        if shift == 63 && byte > 1 {
            return Err(Error::Corrupt("varint overflows u64".into()));
        }
        value |= u64::from(byte & 0x7F) << shift;
        if byte & 0x80 == 0 {
            return Ok(value);
        }
        shift += 7;
        if shift > 63 {
            return Err(Error::Corrupt("varint too long".into()));
        }
    }
}

/// Encoded length of `value` in bytes, without writing it (used by the
/// codec's backend selector to compare frame-inclusive sizes exactly).
pub fn len_u64(value: u64) -> usize {
    let mut n = 1;
    let mut v = value >> 7;
    while v > 0 {
        n += 1;
        v >>= 7;
    }
    n
}

/// Convenience: write a `usize`.
pub fn write_usize(out: &mut Vec<u8>, value: usize) -> usize {
    write_u64(out, value as u64)
}

/// Convenience: read a `usize`, failing if it does not fit.
pub fn read_usize(buf: &[u8], pos: &mut usize) -> Result<usize> {
    let v = read_u64(buf, pos)?;
    usize::try_from(v).map_err(|_| Error::Corrupt("varint exceeds usize".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_edge_values() {
        for v in [0u64, 1, 127, 128, 129, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_u64(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn encoding_lengths() {
        let len = |v: u64| {
            let mut b = Vec::new();
            write_u64(&mut b, v)
        };
        assert_eq!(len(0), 1);
        assert_eq!(len(127), 1);
        assert_eq!(len(128), 2);
        assert_eq!(len(u64::MAX), 10);
    }

    #[test]
    fn len_matches_written_bytes() {
        for v in [0u64, 1, 127, 128, 16383, 16384, 1 << 30, u64::MAX] {
            let mut b = Vec::new();
            assert_eq!(len_u64(v), write_u64(&mut b, v), "v={v}");
        }
    }

    #[test]
    fn truncated_fails() {
        let mut buf = Vec::new();
        write_u64(&mut buf, 300);
        buf.pop();
        let mut pos = 0;
        assert!(read_u64(&buf, &mut pos).is_err());
    }

    #[test]
    fn overlong_fails() {
        // 11 continuation bytes is always invalid for u64.
        let buf = vec![0x80u8; 10];
        let mut pos = 0;
        assert!(read_u64(&buf, &mut pos).is_err());
    }

    #[test]
    fn sequence_roundtrip() {
        let values = [5u64, 0, 1 << 40, 77, 128];
        let mut buf = Vec::new();
        for &v in &values {
            write_u64(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &values {
            assert_eq!(read_u64(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }
}
