//! Crash-recovery property tests for the checkpoint lifecycle
//! (requires `--features fault-inject`).
//!
//! The core harness runs a fixed append/compact/GC workload through the
//! [`FaultFs`] shim once cleanly, recording the cumulative byte offset of
//! every write, then replays the same deterministic workload once per
//! recorded offset with the I/O killed at that byte — tearing the final
//! write exactly there — simulates power loss, reopens the store through
//! the real filesystem, and asserts the recovery invariants:
//!
//! 1. every checkpoint whose mutation was acked (journal fsync returned)
//!    is still visible and restores **bit-exactly**, unless a pending GC
//!    was entitled to remove it;
//! 2. the recovered store exposes nothing beyond the acked state plus, at
//!    most, the single in-flight operation's effect;
//! 3. checkpoint numbering resumes strictly above every acked id.
//!
//! The sweep runs across BF16 and FP8 E4M3 tensor sets. Additional tests
//! cover lying-fsync hardware and `ArchiveReader` corruption parity on
//! mmap vs pread backings.

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use zipnn_lp::checkpoint::fault::{FaultFs, FaultSpec};
use zipnn_lp::checkpoint::{CheckpointStore, CkptKind, GcPolicy, NamedTensor, StoreIo};
use zipnn_lp::codec::{CompressOptions, Compressor, TensorInput};
use zipnn_lp::container::{
    ArchiveReader, ArchiveWriter, ReadBacking, TensorMeta, ARCHIVE_TAIL_LEN, MMAP_SUPPORTED,
};
use zipnn_lp::error::Error;
use zipnn_lp::formats::FloatFormat;
use zipnn_lp::synthetic;
use zipnn_lp::util::rng::Rng;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("zipnn_lp_lifecycle_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn opts_for(format: FloatFormat) -> CompressOptions {
    CompressOptions::for_format(format).with_chunk_size(4096)
}

/// Initial weights for the workload: two small named tensors.
fn fresh(format: FloatFormat, seed: u64) -> Vec<NamedTensor> {
    match format {
        FloatFormat::Bf16 => vec![
            ("layer.w1".to_string(), synthetic::gaussian_bf16_bytes(900, 0.02, seed)),
            ("layer.w2".to_string(), synthetic::gaussian_bf16_bytes(400, 0.05, seed + 1)),
        ],
        _ => {
            let mut rng = Rng::new(seed);
            let mut a = vec![0u8; 1200];
            rng.fill_bytes(&mut a);
            let mut b = vec![0u8; 500];
            rng.fill_bytes(&mut b);
            vec![("layer.w1".to_string(), a), ("layer.w2".to_string(), b)]
        }
    }
}

/// One deterministic training step: sparse in-place mutation.
fn mutate(format: FloatFormat, data: &[u8], seed: u64) -> Vec<u8> {
    match format {
        FloatFormat::Bf16 => synthetic::perturb_bf16_bytes(data, 0.02, 0.3, seed),
        _ => {
            let mut rng = Rng::new(seed);
            let mut out = data.to_vec();
            for byte in out.iter_mut() {
                if rng.next_f64() < 0.08 {
                    *byte = (rng.next_u64() & 0xff) as u8;
                }
            }
            out
        }
    }
}

fn step_weights(
    format: FloatFormat,
    prev: Option<&[NamedTensor]>,
    step: usize,
    seed: u64,
) -> Vec<NamedTensor> {
    match prev {
        None => fresh(format, seed),
        Some(p) => p
            .iter()
            .map(|(n, d)| (n.clone(), mutate(format, d, seed + 1000 + step as u64)))
            .collect(),
    }
}

#[derive(Clone, Copy, Debug)]
enum Op {
    Append,
    CompactTip,
    Gc(GcPolicy),
}

/// Fixed workload: ids 0,1,2 appended (0 full, rest deltas), tip 2
/// compacted to a new base, id 3 appended, GC drops {0,1}, id 4 appended.
const OPS: &[Op] = &[
    Op::Append,
    Op::Append,
    Op::Append,
    Op::CompactTip,
    Op::Append,
    Op::Gc(GcPolicy::KeepLast(2)),
    Op::Append,
];

/// Acked state the crashed store must recover to (the "shadow model").
struct Outcome {
    /// Checkpoints whose append was acked and not removed by an acked GC.
    shadow: BTreeMap<usize, Vec<NamedTensor>>,
    /// Ids the in-flight (errored) GC was entitled to remove.
    pending_removals: Vec<usize>,
    /// Id + content of the in-flight (errored) append, if any.
    pending_append: Option<(usize, Vec<NamedTensor>)>,
    /// Highest id ever acked.
    max_acked: Option<usize>,
    /// Index of the op that hit the injected fault (None = clean run).
    failed_at: Option<usize>,
}

/// Ids a GC policy may remove, ignoring chain-closure protection (a
/// superset of what [`CheckpointStore::gc`] actually removes — slack the
/// recovery invariant is allowed).
fn gc_candidates(store: &CheckpointStore, policy: GcPolicy) -> Vec<usize> {
    let ids: Vec<usize> = store.records().iter().map(|r| r.id).collect();
    match policy {
        GcPolicy::KeepLast(n) => {
            let keep: BTreeSet<usize> = ids.iter().rev().take(n).copied().collect();
            ids.into_iter().filter(|i| !keep.contains(i)).collect()
        }
        GcPolicy::KeepBases => store
            .records()
            .iter()
            .filter(|r| r.kind != CkptKind::Full)
            .map(|r| r.id)
            .collect(),
    }
}

fn run_workload(dir: &Path, io: Arc<dyn StoreIo>, format: FloatFormat, seed: u64) -> Outcome {
    let mut out = Outcome {
        shadow: BTreeMap::new(),
        pending_removals: Vec::new(),
        pending_append: None,
        max_acked: None,
        failed_at: None,
    };
    let mut store = match CheckpointStore::open_with_io(dir, opts_for(format), 100, io) {
        Ok(s) => s,
        Err(_) => {
            out.failed_at = Some(0);
            return out;
        }
    };
    let mut weights: Option<Vec<NamedTensor>> = None;
    for (i, op) in OPS.iter().enumerate() {
        match op {
            Op::Append => {
                let next = step_weights(format, weights.as_deref(), i, seed);
                let id = store.next_id();
                match store.append(&next) {
                    Ok(rec) => {
                        let rid = rec.id;
                        out.shadow.insert(rid, next.clone());
                        out.max_acked = Some(out.max_acked.map_or(rid, |m| m.max(rid)));
                        weights = Some(next);
                    }
                    Err(_) => {
                        out.pending_append = Some((id, next));
                        out.failed_at = Some(i);
                        return out;
                    }
                }
            }
            Op::CompactTip => {
                let Some(tip) = store.records().last().map(|r| r.id) else {
                    continue;
                };
                if store.compact(tip).is_err() {
                    out.failed_at = Some(i);
                    return out;
                }
            }
            Op::Gc(policy) => {
                let candidates = gc_candidates(&store, *policy);
                match store.gc(*policy) {
                    Ok(removed) => {
                        for id in removed {
                            out.shadow.remove(&id);
                        }
                    }
                    Err(_) => {
                        out.pending_removals = candidates;
                        out.failed_at = Some(i);
                        return out;
                    }
                }
            }
        }
    }
    out
}

/// Reopen `dir` through the real filesystem and assert the recovery
/// invariants against the shadow model. `durable` is false for the
/// lying-fsync scenario, where acked state may legitimately be lost and
/// only the subset + bit-exactness + monotonicity bounds apply.
fn check_recovery(dir: &Path, out: &Outcome, format: FloatFormat, durable: bool) {
    let mut store = CheckpointStore::open(dir, opts_for(format), 100)
        .expect("post-crash open must always succeed");
    if durable {
        for (id, tensors) in &out.shadow {
            match store.record(*id) {
                Ok(_) => assert!(
                    store.verify(*id, tensors).unwrap(),
                    "acked checkpoint {id} does not restore bit-exactly"
                ),
                Err(_) => assert!(
                    out.pending_removals.contains(id),
                    "acked checkpoint {id} vanished with no GC in flight"
                ),
            }
        }
    }
    let visible: Vec<usize> = store.records().iter().map(|r| r.id).collect();
    for id in &visible {
        if out.shadow.contains_key(id) {
            if !durable {
                assert!(
                    store.verify(*id, &out.shadow[id]).unwrap(),
                    "visible checkpoint {id} does not restore bit-exactly"
                );
            }
            continue;
        }
        match &out.pending_append {
            Some((pid, tensors)) if pid == id => assert!(
                store.verify(*id, tensors).unwrap(),
                "in-flight checkpoint {id} is visible but not bit-exact"
            ),
            _ => panic!("recovered store exposes unexpected checkpoint {id}"),
        }
    }
    // Numbering resumes monotonically: strictly above every acked id and
    // every visible id.
    let probe = fresh(format, 999_999);
    let new_id = store.append(&probe).expect("recovered store must accept appends").id;
    if durable {
        if let Some(m) = out.max_acked {
            assert!(new_id > m, "new id {new_id} reuses acked numbering (max acked {m})");
        }
    }
    for v in &visible {
        assert!(new_id > *v, "new id {new_id} not above visible id {v}");
    }
    assert!(store.verify(new_id, &probe).unwrap());
}

/// Every recorded write boundary plus the byte just before it (tearing
/// the write's final byte), down-sampled to keep the sweep bounded.
fn kill_points(offsets: &[u64]) -> Vec<u64> {
    let mut set = BTreeSet::new();
    for &b in offsets {
        set.insert(b);
        if b > 0 {
            set.insert(b - 1);
        }
    }
    let all: Vec<u64> = set.into_iter().collect();
    const MAX_POINTS: usize = 200;
    if all.len() <= MAX_POINTS {
        return all;
    }
    let stride = all.len().div_ceil(MAX_POINTS);
    let mut sampled: Vec<u64> = all.iter().step_by(stride).copied().collect();
    // Always keep the final boundaries — the GC/compact endgame.
    for &b in all.iter().rev().take(8) {
        if !sampled.contains(&b) {
            sampled.push(b);
        }
    }
    sampled.sort_unstable();
    sampled
}

fn fault_sweep(format: FloatFormat, seed: u64, tag: &str) {
    let base = tmpdir(tag);
    // Clean run through the shim: learns the write schedule and pins the
    // expected end state.
    let clean_dir = base.join("clean");
    let fs = FaultFs::new();
    let out = run_workload(&clean_dir, Arc::new(fs.clone()), format, seed);
    assert_eq!(out.failed_at, None, "clean run must not fail");
    assert_eq!(
        out.shadow.keys().copied().collect::<Vec<_>>(),
        vec![2, 3, 4],
        "workload end state changed — update the test's expectations"
    );
    check_recovery(&clean_dir, &out, format, true);
    let points = kill_points(&fs.write_offsets());
    assert!(points.len() >= 20, "suspiciously few write points: {}", points.len());
    for (i, &k) in points.iter().enumerate() {
        let dir = base.join(format!("k{i}"));
        let fs = FaultFs::new();
        fs.arm(FaultSpec { kill_at_write_byte: Some(k), ..FaultSpec::default() });
        let out = run_workload(&dir, Arc::new(fs.clone()), format, seed);
        fs.crash().unwrap();
        check_recovery(&dir, &out, format, true);
        std::fs::remove_dir_all(&dir).ok();
    }
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn crash_sweep_recovers_bf16_store_at_every_write_boundary() {
    fault_sweep(FloatFormat::Bf16, 41, "sweep_bf16");
}

#[test]
fn crash_sweep_recovers_fp8_e4m3_store_at_every_write_boundary() {
    fault_sweep(FloatFormat::Fp8E4M3, 43, "sweep_fp8");
}

#[test]
fn lying_fsync_loses_only_the_unsynced_suffix() {
    let dir = tmpdir("dropfsync");
    let fs = FaultFs::new();
    let io: Arc<dyn StoreIo> = Arc::new(fs.clone());
    let format = FloatFormat::Bf16;
    let mut store = CheckpointStore::open_with_io(&dir, opts_for(format), 100, io).unwrap();
    // Two checkpoints written with honored fsyncs: durable.
    let w0 = fresh(format, 7);
    let w1 = step_weights(format, Some(&w0), 1, 7);
    store.append(&w0).unwrap();
    store.append(&w1).unwrap();
    // From here on every fsync silently does nothing.
    fs.arm(FaultSpec { drop_fsync: true, ..FaultSpec::default() });
    let w2 = step_weights(format, Some(&w1), 2, 7);
    let w3 = step_weights(format, Some(&w2), 3, 7);
    store.append(&w2).unwrap();
    store.append(&w3).unwrap();
    assert_eq!(store.len(), 4);
    drop(store);
    fs.crash().unwrap();
    // Only the fsync-honored prefix survives; it restores bit-exactly and
    // numbering resumes after it.
    let mut store = CheckpointStore::open(&dir, opts_for(format), 100).unwrap();
    let visible: Vec<usize> = store.records().iter().map(|r| r.id).collect();
    assert_eq!(visible, vec![0, 1], "exactly the durable prefix survives");
    assert!(store.verify(0, &w0).unwrap());
    assert!(store.verify(1, &w1).unwrap());
    let rec_id = store.append(&w2).unwrap().id;
    assert_eq!(rec_id, 2);
    std::fs::remove_dir_all(&dir).ok();
}

/// Build one v2 archive through the shim, returning its bytes.
fn write_archive(
    fs: &FaultFs,
    path: &Path,
    blob: &zipnn_lp::codec::CompressedBlob,
) -> zipnn_lp::Result<()> {
    let f = fs.create(path)?;
    let mut w = ArchiveWriter::new(f)?;
    w.add(TensorMeta { name: "t".into(), shape: vec![9000] }, blob)?;
    let mut f = w.finish()?;
    f.sync()
}

#[test]
fn archive_corruption_classifies_identically_on_mmap_and_pread() {
    let dir = tmpdir("backing_parity");
    let path = dir.join("a.zlp");
    let session = Compressor::new(opts_for(FloatFormat::Bf16).with_chunk_size(2048));
    let data = synthetic::gaussian_bf16_bytes(9000, 0.02, 77);
    let blob = session.compress(TensorInput::Tensor(&data)).unwrap();
    let fs = FaultFs::new();
    write_archive(&fs, &path, &blob).unwrap();
    let good = std::fs::read(&path).unwrap();
    let n = good.len();
    let footer_offset =
        u64::from_le_bytes(good[n - ARCHIVE_TAIL_LEN..n - ARCHIVE_TAIL_LEN + 8].try_into().unwrap())
            as usize;
    let backings: Vec<ReadBacking> = if MMAP_SUPPORTED {
        vec![ReadBacking::Pread, ReadBacking::Mmap]
    } else {
        vec![ReadBacking::Pread]
    };

    // Torn writes, produced by the shim's kill point rather than manual
    // truncation: the archive build dies mid-write, leaving exactly the
    // prefix on disk. Each damaged file must be a typed `Corrupt` carrying
    // a byte offset — identically on every backing.
    let cuts: [u64; 4] = [
        (n - 6) as u64,                       // inside the 16-byte tail (footer CRC cut)
        (footer_offset + 3) as u64,           // mid-directory
        footer_offset.saturating_sub(5) as u64, // mid-chunk data
        10,                                   // barely past the header
    ];
    for cut in cuts {
        fs.arm(FaultSpec { kill_at_write_byte: Some(cut), ..FaultSpec::default() });
        assert!(write_archive(&fs, &path, &blob).is_err(), "kill at {cut} must tear the build");
        assert_eq!(std::fs::metadata(&path).unwrap().len(), cut, "torn file keeps the prefix");
        for b in &backings {
            let e = ArchiveReader::open_with(&path, *b).unwrap_err();
            assert!(matches!(e, Error::Corrupt(_)), "cut {cut} on {b:?}: wrong variant: {e}");
            assert!(e.to_string().contains("byte"), "cut {cut} on {b:?}: no byte offset: {e}");
        }
    }

    // Footer bitflip: caught by the footer CRC at open, on every backing.
    fs.arm(FaultSpec::default());
    let mut bad = good.clone();
    bad[footer_offset + 2] ^= 0x01;
    {
        let mut f = fs.create(&path).unwrap();
        f.write_all(&bad).unwrap();
        f.sync().unwrap();
    }
    for b in &backings {
        let e = ArchiveReader::open_with(&path, *b).unwrap_err();
        assert!(matches!(e, Error::Corrupt(_)), "footer flip on {b:?}: {e}");
    }

    // Chunk-data bitflip: the footer is intact so the archive opens, but
    // the chunk CRC rejects the read — on every backing.
    let mut bad = good.clone();
    bad[16] ^= 0x40;
    {
        let mut f = fs.create(&path).unwrap();
        f.write_all(&bad).unwrap();
        f.sync().unwrap();
    }
    for b in &backings {
        let reader = ArchiveReader::open_with(&path, *b).unwrap();
        assert!(reader.read_tensor("t").is_err(), "data flip undetected on {b:?}");
    }

    // And the pristine bytes round-trip on every backing, proving the
    // damage (not the harness) caused the failures above.
    {
        let mut f = fs.create(&path).unwrap();
        f.write_all(&good).unwrap();
        f.sync().unwrap();
    }
    for b in &backings {
        let reader = ArchiveReader::open_with(&path, *b).unwrap();
        assert_eq!(reader.read_tensor("t").unwrap(), data, "pristine read on {b:?}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
