//! Integration tests over the real PJRT engine + AOT artifacts.
//!
//! These need `artifacts/` (built by `make artifacts`); they skip (with a
//! message) when it is absent so `cargo test` stays green on a fresh clone.
//! One PJRT client per process: tests share a lazily-initialized runtime.

use std::path::PathBuf;

use zipnn_lp::coordinator::{BatchPolicy, Request, Server};
use zipnn_lp::formats::conv::f32_to_e4m3;
use zipnn_lp::formats::{split_streams, FloatFormat};
use zipnn_lp::model::ModelRuntime;

fn artifacts_dir() -> Option<PathBuf> {
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    candidates.into_iter().find(|p| p.join("manifest.json").exists())
}

/// PJRT clients are not Sync, so each test loads its own runtime.
/// Returns None (test skips) when artifacts/ has not been built.
fn load_model() -> Option<ModelRuntime> {
    let dir = artifacts_dir()?;
    match ModelRuntime::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping PJRT test: {e}");
            None
        }
    }
}

macro_rules! require_model {
    () => {
        match load_model() {
            Some(m) => m,
            None => {
                eprintln!("artifacts/ missing — run `make artifacts`; skipping");
                return;
            }
        }
    };
}

#[test]
fn engine_loads_all_artifacts() {
    let m = require_model!();
    let mut names = m.engine().artifact_names();
    names.sort();
    assert_eq!(
        names,
        vec!["decode", "nvfp4", "prefill", "quantize_e4m3", "split_bf16", "train_step"]
    );
    assert_eq!(m.weights().len(), m.engine().manifest.weight_names.len());
}

#[test]
fn split_kernel_matches_native_split() {
    let m = require_model!();
    let data = zipnn_lp::synthetic::gaussian_bf16_bytes(5_000, 0.02, 42);
    let words: Vec<u16> = data
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect();
    let (exp, sm, hist) = m.split_bf16_xla(&words).unwrap();
    let set = split_streams(FloatFormat::Bf16, &data).unwrap();
    assert_eq!(exp, set.exponent().unwrap().bytes, "XLA exp == native exp");
    assert_eq!(sm, set.sign_mantissa().unwrap().bytes, "XLA s+m == native s+m");
    let native_hist = zipnn_lp::entropy::Histogram::from_bytes(&exp);
    assert_eq!(&hist[..], &native_hist.counts()[..], "histogram agrees");
}

#[test]
fn quantize_kernel_matches_native_conv() {
    let m = require_model!();
    let vals = zipnn_lp::synthetic::gaussian_f32(4_096, 0.5, 7);
    let xla = m.quantize_e4m3_xla(&vals).unwrap();
    // Quirk of this runtime: xla_extension 0.5.1's CPU backend converts
    // f32→f8e4m3fn THROUGH f16 (double rounding), so inputs that land
    // exactly on an E4M3 tie after the f16 step can differ by one code
    // from direct RNE (jax ≥0.5's own CPU backend, which the pytest suite
    // validates, rounds directly). Accept either the direct-RNE code or
    // the via-f16 double-rounded code; anything else is a real bug.
    let mut double_rounded = 0usize;
    for (i, (&got, &v)) in xla.iter().zip(&vals).enumerate() {
        let direct = f32_to_e4m3(v);
        if got == direct {
            continue;
        }
        let via_f16 = f32_to_e4m3(zipnn_lp::formats::conv::fp16_to_f32(
            zipnn_lp::formats::conv::f32_to_fp16(v),
        ));
        assert_eq!(got, via_f16, "idx {i}: v={v:e} not direct ({direct:#04x}) nor via-f16");
        double_rounded += 1;
    }
    // Double-rounding boundary hits are rare (<2% of Gaussian inputs).
    assert!(double_rounded < vals.len() / 50, "{double_rounded} double-rounded codes");
}

#[test]
fn nvfp4_kernel_matches_native_quantizer() {
    let m = require_model!();
    let n = m.dims().kernel_n; // exact fit avoids padding distortion
    let vals = zipnn_lp::synthetic::gaussian_f32(n, 0.3, 9);
    let xla = m.quantize_nvfp4_xla(&vals).unwrap();
    let native = zipnn_lp::formats::conv::quantize_nvfp4(&vals);
    assert_eq!(xla.payload, native.payload);
    assert_eq!(xla.block_scales, native.block_scales);
    assert!((xla.global_scale - native.global_scale).abs() <= native.global_scale * 1e-6);
}

#[test]
fn train_step_reduces_loss() {
    let mut m = require_model!();
    let dims = m.dims();
    let mut rng = zipnn_lp::util::rng::Rng::new(0);
    let mk = |rng: &mut zipnn_lp::util::rng::Rng| -> Vec<i32> {
        let (b, s, v) = (dims.batch, dims.max_seq, dims.vocab as u64);
        let mut out = vec![0i32; b * s];
        for row in 0..b {
            let mut tok = rng.below(v);
            out[row * s] = tok as i32;
            for t in 1..s {
                tok = if rng.next_f64() < 0.15 { rng.below(v) } else { (tok * 31 + 17) % v };
                out[row * s + t] = tok as i32;
            }
        }
        out
    };
    let first = m.train_step(&mk(&mut rng), 0.1).unwrap();
    let mut last = first;
    for _ in 0..8 {
        last = m.train_step(&mk(&mut rng), 0.1).unwrap();
    }
    assert!(last.is_finite());
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn decode_is_consistent_with_prefill() {
    let m = require_model!();
    let dims = m.dims();
    let (b, s, l, d, v) = (dims.batch, dims.max_seq, dims.n_layers, dims.d_model, dims.vocab);
    let mut rng = zipnn_lp::util::rng::Rng::new(11);
    let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(v as u64) as i32).collect();
    let pre = m.prefill(&tokens).unwrap();

    // Replay the first few positions through decode over an f32 cache.
    let mut k_slab = vec![0f32; l * b * s * d];
    let mut v_slab = vec![0f32; l * b * s * d];
    for t in 0..4usize {
        let token: Vec<i32> = (0..b).map(|slot| tokens[slot * s + t]).collect();
        let pos = vec![t as i32; b];
        let out = m.decode_step(&token, &pos, &k_slab, &v_slab).unwrap();
        // Logits must match the prefill logits at position t.
        for slot in 0..b {
            let dec = &out.logits[slot * v..(slot + 1) * v];
            let pre_row = &pre.logits[(slot * s + t) * v..(slot * s + t + 1) * v];
            for (a, bb) in dec.iter().zip(pre_row) {
                assert!(
                    (a - bb).abs() <= 2e-3 + a.abs().max(bb.abs()) * 2e-3,
                    "slot {slot} t {t}: {a} vs {bb}"
                );
            }
        }
        // Write the new K/V rows into the slab for the next step.
        for layer in 0..l {
            for slot in 0..b {
                let src = (layer * b + slot) * d;
                let dst = ((layer * b + slot) * s + t) * d;
                k_slab[dst..dst + d].copy_from_slice(&out.k_new[src..src + d]);
                v_slab[dst..dst + d].copy_from_slice(&out.v_new[src..src + d]);
            }
        }
    }
}

#[test]
fn serving_compression_is_transparent_on_real_model() {
    let dir = match artifacts_dir() {
        Some(d) => d,
        None => {
            eprintln!("artifacts/ missing; skipping");
            return;
        }
    };
    // Each server needs its own ModelRuntime (Server consumes the model);
    // load two fresh ones from the same artifacts.
    let reqs: Vec<Request> = (0..4)
        .map(|i| Request {
            id: i,
            prompt: vec![(i as i32 * 7 + 3) % 512, 5, 9, 2 + i as i32],
            max_new_tokens: 6,
        })
        .collect();
    let run = |compression: bool, format: FloatFormat| {
        let model = ModelRuntime::load(&dir).unwrap();
        let mut server =
            Server::new(model, format, BatchPolicy::default(), compression).unwrap();
        server.run(reqs.clone()).unwrap()
    };
    for format in [FloatFormat::Bf16, FloatFormat::Fp8E4M3] {
        let on = run(true, format);
        let off = run(false, format);
        for (a, b) in on.iter().zip(&off) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "{format:?}");
            assert!(!a.tokens.is_empty());
        }
    }
}

#[test]
fn serving_reports_kv_compression() {
    let dir = match artifacts_dir() {
        Some(d) => d,
        None => {
            eprintln!("artifacts/ missing; skipping");
            return;
        }
    };
    let model = ModelRuntime::load(&dir).unwrap();
    let mut server =
        Server::new(model, FloatFormat::Bf16, BatchPolicy::default(), true).unwrap();
    let reqs: Vec<Request> = (0..4)
        .map(|i| Request { id: i, prompt: vec![1, 2, 3 + i as i32], max_new_tokens: 20 })
        .collect();
    let _ = server.run(reqs).unwrap();
    let stats = server.stats();
    assert!(stats.cache.sealed_pages > 0);
    // Real-model BF16 K/V exponents must compress well (§4.3).
    assert!(stats.cache.exp_ratio() < 0.6, "exp ratio {}", stats.cache.exp_ratio());
    assert!(stats.cache.ratio() < 1.0);
    assert_eq!(stats.completed, 4);
}
