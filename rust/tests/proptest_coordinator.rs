//! Property tests on coordinator invariants, using a mock model so no PJRT
//! artifacts are needed (in-house seeded harness; no proptest crate in the
//! baked registry).
//!
//! Invariants checked across randomized request mixes:
//!  * no request lost or duplicated; response ids preserve submit order;
//!  * generated-token counts follow the (max_new_tokens, max_seq) contract;
//!  * the compressed K/V cache is bit-exact: enabling compression changes
//!    *no* generated token;
//!  * batch bound respected (mock rejects wider calls by construction);
//!  * sequences are evicted after completion (no cache leak).

use zipnn_lp::coordinator::{BatchPolicy, DecoderModel, Request, Server};
use zipnn_lp::error::Result;
use zipnn_lp::formats::FloatFormat;
use zipnn_lp::model::{DecodeOut, PrefillOut};
use zipnn_lp::runtime::ModelDims;
use zipnn_lp::util::rng::Rng;

/// Deterministic fake transformer: K/V rows and logits are hash functions
/// of (token, position, layer, channel), so any cache corruption or
/// mis-assembly changes the output tokens.
#[derive(Clone)]
struct MockModel {
    dims: ModelDims,
}

impl MockModel {
    fn new(batch: usize, max_seq: usize) -> Self {
        MockModel {
            dims: ModelDims {
                vocab: 97,
                d_model: 8,
                n_layers: 2,
                n_heads: 2,
                head_dim: 4,
                max_seq,
                batch,
                kernel_n: 64,
            },
        }
    }

    fn kv_val(&self, token: i32, pos: usize, layer: usize, c: usize) -> f32 {
        // Deterministic values drawn from a few binades — clustered like
        // real normalized activations, so pages compress even at size 16.
        let h = (token as i64 * 37 + pos as i64 * 11 + layer as i64 * 5 + c as i64) % 8;
        0.5 + h as f32 * 0.0625
    }

    /// Logits depend on the *sum* of cached K values visible at this step,
    /// so a single wrong cache row changes the argmax.
    fn logits_row(&self, token: i32, cache_sum: f32) -> Vec<f32> {
        let v = self.dims.vocab;
        let base = (token as i64 * 31 + 17).rem_euclid(v as i64) as usize;
        let shift = (cache_sum * 1000.0).round() as i64;
        let winner = ((base as i64 + shift).rem_euclid(v as i64)) as usize;
        let mut row = vec![0.0f32; v];
        row[winner] = 1.0;
        row
    }
}

impl DecoderModel for MockModel {
    fn dims(&self) -> ModelDims {
        self.dims
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let d = self.dims;
        let (b, s, dm, l, v) = (d.batch, d.max_seq, d.d_model, d.n_layers, d.vocab);
        assert_eq!(tokens.len(), b * s, "mock: prefill batch bound violated");
        let mut k = vec![0f32; l * b * s * dm];
        let mut vv = vec![0f32; l * b * s * dm];
        let mut logits = vec![0f32; b * s * v];
        for slot in 0..b {
            let mut cache_sum = 0.0f32;
            for t in 0..s {
                let tok = tokens[slot * s + t];
                for layer in 0..l {
                    for c in 0..dm {
                        let val = self.kv_val(tok, t, layer, c);
                        let idx = ((layer * b + slot) * s + t) * dm + c;
                        k[idx] = val;
                        vv[idx] = val * 0.5;
                        if layer == 0 {
                            cache_sum += val;
                        }
                    }
                }
                let row = self.logits_row(tok, cache_sum);
                logits[(slot * s + t) * v..(slot * s + t + 1) * v].copy_from_slice(&row);
            }
        }
        Ok(PrefillOut { logits, k_cache: k, v_cache: vv })
    }

    fn decode_step(&self, token: &[i32], pos: &[i32], k: &[f32], _v: &[f32])
        -> Result<DecodeOut> {
        let d = self.dims;
        let (b, s, dm, l, v) = (d.batch, d.max_seq, d.d_model, d.n_layers, d.vocab);
        assert_eq!(token.len(), b, "mock: decode batch bound violated");
        let mut logits = vec![0f32; b * v];
        let mut kn = vec![0f32; l * b * dm];
        let mut vn = vec![0f32; l * b * dm];
        for slot in 0..b {
            let p = pos[slot] as usize;
            // Sum layer-0 cached K rows 0..p (the cache the scheduler fed).
            let mut cache_sum = 0.0f32;
            for t in 0..p {
                for c in 0..dm {
                    cache_sum += k[((0 * b + slot) * s + t) * dm + c];
                }
            }
            // Include the current token's own K (the jax model writes it
            // into the cache before attention).
            for layer in 0..l {
                for c in 0..dm {
                    let val = self.kv_val(token[slot], p, layer, c);
                    kn[(layer * b + slot) * dm + c] = val;
                    vn[(layer * b + slot) * dm + c] = val * 0.5;
                    if layer == 0 {
                        cache_sum += val;
                    }
                }
            }
            let row = self.logits_row(token[slot], cache_sum);
            logits[slot * v..(slot + 1) * v].copy_from_slice(&row);
        }
        Ok(DecodeOut { logits, k_new: kn, v_new: vn })
    }
}

fn random_requests(rng: &mut Rng, n: usize, max_seq: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: 1000 + i as u64,
            prompt: (0..(1 + rng.below((max_seq - 2) as u64) as usize))
                .map(|_| rng.below(97) as i32)
                .collect(),
            max_new_tokens: rng.below(12) as usize,
        })
        .collect()
}

fn run_server(
    requests: Vec<Request>,
    compression: bool,
    format: FloatFormat,
    batch: usize,
    max_seq: usize,
) -> Vec<zipnn_lp::coordinator::Response> {
    let model = MockModel::new(batch, max_seq);
    let mut server = Server::new(model, format, BatchPolicy::default(), compression).unwrap();
    server.run(requests).unwrap()
}

#[test]
fn prop_no_request_lost_or_reordered() {
    let mut rng = Rng::new(1);
    for case in 0..30 {
        let n = 1 + rng.below(11) as usize;
        let reqs = random_requests(&mut rng, n, 16);
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let resp = run_server(reqs, true, FloatFormat::Bf16, 3, 16);
        let got: Vec<u64> = resp.iter().map(|r| r.id).collect();
        assert_eq!(got, ids, "case {case}");
    }
}

#[test]
fn prop_token_count_contract() {
    let mut rng = Rng::new(2);
    for case in 0..30 {
        let n = 1 + rng.below(7) as usize;
        let max_seq = 16;
        let reqs = random_requests(&mut rng, n, max_seq);
        let expects: Vec<usize> = reqs
            .iter()
            .map(|r| {
                if r.max_new_tokens == 0 {
                    0
                } else {
                    r.max_new_tokens.min(max_seq - r.prompt.len())
                }
            })
            .collect();
        let resp = run_server(reqs, true, FloatFormat::Bf16, 2, max_seq);
        for (r, want) in resp.iter().zip(&expects) {
            assert_eq!(r.tokens.len(), *want, "case {case} id {}", r.id);
        }
    }
}

#[test]
fn prop_compression_is_transparent() {
    // The core lossless claim at the serving level: identical tokens with
    // the codec on and off, for both cache formats.
    let mut rng = Rng::new(3);
    for case in 0..20 {
        let n = 1 + rng.below(9) as usize;
        let reqs = random_requests(&mut rng, n, 16);
        for format in [FloatFormat::Bf16, FloatFormat::Fp8E4M3, FloatFormat::Fp8E5M2] {
            let on = run_server(reqs.clone(), true, format, 3, 16);
            let off = run_server(reqs.clone(), false, format, 3, 16);
            assert_eq!(on.len(), off.len());
            for (a, b) in on.iter().zip(&off) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.tokens, b.tokens, "case {case} {format:?} id {}", a.id);
            }
        }
    }
}

#[test]
fn prop_determinism() {
    let mut rng = Rng::new(4);
    for _ in 0..10 {
        let reqs = random_requests(&mut rng, 5, 12);
        let a = run_server(reqs.clone(), true, FloatFormat::Fp8E4M3, 2, 12);
        let b = run_server(reqs, true, FloatFormat::Fp8E4M3, 2, 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}

#[test]
fn prop_invalid_requests_rejected() {
    let model = MockModel::new(2, 8);
    let mut server = Server::new(model, FloatFormat::Bf16, BatchPolicy::default(), true).unwrap();
    // Empty prompt.
    assert!(server
        .run(vec![Request { id: 1, prompt: vec![], max_new_tokens: 3 }])
        .is_err());
    // Prompt filling the whole context.
    assert!(server
        .run(vec![Request { id: 2, prompt: vec![1; 8], max_new_tokens: 3 }])
        .is_err());
    // Server remains usable after rejection.
    let ok = server
        .run(vec![Request { id: 3, prompt: vec![1, 2], max_new_tokens: 2 }])
        .unwrap();
    assert_eq!(ok.len(), 1);
    assert_eq!(ok[0].tokens.len(), 2);
}

#[test]
fn prop_cache_actually_compresses_under_mock() {
    // The mock's smooth K/V values are compressible; stats must show it.
    let mut rng = Rng::new(5);
    let reqs = random_requests(&mut rng, 6, 16);
    let model = MockModel::new(3, 16);
    let mut server =
        Server::new(model, FloatFormat::Bf16, BatchPolicy::default(), true).unwrap();
    let _ = server.run(reqs).unwrap();
    let stats = server.stats();
    assert!(stats.cache.sealed_pages > 0);
    assert!(stats.cache.exp_ratio() < 0.9, "exp {}", stats.cache.exp_ratio());
    assert!(stats.completed == 6);
}
