//! Property tests for the shared memory-budgeted K/V pool (in-house seeded
//! harness; no proptest crate in the baked registry).
//!
//! The central invariant: against a **shadow uncompressed cache** (a plain
//! `Vec<u8>` per (sequence, layer)), every pool read is bit-exact — across
//! random interleavings of appends, reads, and sequence evictions, for BF16
//! and FP8 E4M3, under a budget small enough that pages constantly spill to
//! disk and reload. Also checked: the in-memory high-water mark respects
//! the budget (single-threaded schedules have no busy-victim corner), and
//! concurrent appenders/readers on a shared pool stay bit-exact.

use std::collections::BTreeMap;
use zipnn_lp::formats::FloatFormat;
use zipnn_lp::kvcache::KvCacheConfig;
use zipnn_lp::pool::{PoolConfig, SharedKvPool};
use zipnn_lp::synthetic;
use zipnn_lp::util::rng::Rng;

const N_LAYERS: usize = 2;
const LIVE_SEQS: usize = 5;

fn config_for(format: FloatFormat) -> KvCacheConfig {
    let elem = FloatFormat::byte_width(format).unwrap_or(1);
    let mut c = KvCacheConfig::new(N_LAYERS, 64 * elem, format);
    c.page_tokens = 8;
    c
}

fn token_bytes(config: &KvCacheConfig, seed: u64) -> Vec<u8> {
    synthetic::kv_token_bytes(config, seed)
}

/// Randomly interleave appends / reads / sequence evictions across ≥ 4 live
/// sequences, asserting every read against the shadow cache.
fn run_interleaved(format: FloatFormat, seed: u64) {
    let config = config_for(format);
    // Must cover the hot pages (10 lists x <= 2 KiB) plus one materialized
    // read list, while staying far below the ~hundreds-of-KiB raw total so
    // eviction runs constantly.
    let budget = 128 * 1024;
    let pool =
        SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
    let mut rng = Rng::new(seed);
    let mut shadows: BTreeMap<(u64, usize), Vec<u8>> = BTreeMap::new();
    let mut live: Vec<u64> = (1..=LIVE_SEQS as u64).collect();
    let mut next_seq = LIVE_SEQS as u64 + 1;
    let mut reads = 0u64;
    for step in 0..3000u64 {
        let op = rng.below(100);
        let seq = live[rng.below(live.len() as u64) as usize];
        let layer = rng.below(N_LAYERS as u64) as usize;
        if op < 62 {
            let kv = token_bytes(&config, step * 7919 + seq * 131 + layer as u64);
            pool.append_token(seq, layer, &kv).unwrap();
            shadows.entry((seq, layer)).or_default().extend_from_slice(&kv);
        } else if op < 97 {
            match shadows.get(&(seq, layer)) {
                Some(shadow) => {
                    assert_eq!(&pool.read(seq, layer).unwrap(), shadow, "step {step}");
                    reads += 1;
                }
                None => assert!(pool.read(seq, layer).is_err(), "step {step}"),
            }
        } else {
            // Retire one sequence, admit a fresh one (session churn).
            pool.evict_sequence(seq);
            shadows.retain(|&(s, _), _| s != seq);
            live.retain(|&s| s != seq);
            live.push(next_seq);
            next_seq += 1;
        }
    }
    // Final sweep: everything still live must read back bit-exactly.
    for (&(seq, layer), shadow) in &shadows {
        assert_eq!(&pool.read(seq, layer).unwrap(), shadow, "final seq {seq}");
    }
    let c = pool.counters();
    assert!(reads > 100, "schedule degenerate: only {reads} reads");
    assert!(c.spills > 0, "budget never forced a spill: {c}");
    assert!(c.reloads > 0, "no spill → reload round trip exercised: {c}");
    assert!(
        c.within_budget(),
        "single-threaded schedule must never violate the budget: {c}"
    );
}

#[test]
fn prop_interleaved_ops_bit_exact_bf16() {
    run_interleaved(FloatFormat::Bf16, 11);
}

#[test]
fn prop_interleaved_ops_bit_exact_fp8_e4m3() {
    run_interleaved(FloatFormat::Fp8E4M3, 13);
}

#[test]
fn prop_concurrent_sequences_bit_exact() {
    // 8 sequences on 4 threads sharing one budgeted pool: every thread
    // checks its own sequences against private shadows while eviction
    // steals pages across threads.
    let config = config_for(FloatFormat::Bf16);
    let budget = 160 * 1024;
    let pool =
        SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
    let n_threads = 4u64;
    let per_thread = 2u64;
    std::thread::scope(|scope| {
        for w in 0..n_threads {
            let pool = &pool;
            let config = &config;
            scope.spawn(move || {
                let seqs: Vec<u64> =
                    (0..per_thread).map(|i| 1 + w * per_thread + i).collect();
                let mut shadows: BTreeMap<(u64, usize), Vec<u8>> = BTreeMap::new();
                for t in 0..220u64 {
                    for &seq in &seqs {
                        for layer in 0..N_LAYERS {
                            let kv =
                                token_bytes(config, seq * 100_003 + t * 17 + layer as u64);
                            pool.append_token(seq, layer, &kv).unwrap();
                            shadows.entry((seq, layer)).or_default().extend_from_slice(&kv);
                        }
                    }
                    if t % 50 == 49 {
                        for (&(seq, layer), shadow) in &shadows {
                            assert_eq!(
                                &pool.read(seq, layer).unwrap(),
                                shadow,
                                "seq {seq} layer {layer} t {t}"
                            );
                        }
                    }
                }
                for (&(seq, layer), shadow) in &shadows {
                    assert_eq!(&pool.read(seq, layer).unwrap(), shadow);
                }
            });
        }
    });
    let c = pool.counters();
    assert!(c.spills > 0, "concurrent scenario never spilled: {c}");
    assert!(c.reloads > 0, "concurrent scenario never reloaded: {c}");
    // 8 seqs x 2 layers x 220 tokens x 256 B = 880 KiB raw >> 160 KiB.
    assert!(pool.stats().raw_bytes > budget);
}
