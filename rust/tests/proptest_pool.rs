//! Property tests for the shared memory-budgeted K/V pool (in-house seeded
//! harness; no proptest crate in the baked registry).
//!
//! The central invariant: against a **shadow uncompressed cache** (a plain
//! `Vec<u8>` per (sequence, layer)), every pool read is bit-exact — across
//! random interleavings of appends, snapshot reads, and sequence evictions,
//! for BF16 and FP8 E4M3, under a budget small enough that pages constantly
//! spill to disk and reload. Readers hold live [`KvSnapshot`]s across the
//! churn: a held snapshot must keep reading its *capture-time* bytes
//! bit-exactly no matter how many seal/evict/spill-reload cycles (or even
//! whole-sequence evictions) happen after it was taken, and the in-memory
//! high-water mark must still respect the budget — the evictor's credited
//! headroom has to account for stash-pinned pages.

use std::collections::BTreeMap;
use zipnn_lp::formats::FloatFormat;
use zipnn_lp::kvcache::KvCacheConfig;
use zipnn_lp::pool::{KvSnapshot, PoolConfig, SharedKvPool};
use zipnn_lp::synthetic;
use zipnn_lp::util::rng::Rng;

const N_LAYERS: usize = 2;
const LIVE_SEQS: usize = 5;
/// Live pinned snapshots held across operations at any one time. Each can
/// pin its whole sequence's encoded pages into the stash, so the budget
/// headroom math below must cover `MAX_HELD` stashed sequences.
const MAX_HELD: usize = 2;

fn config_for(format: FloatFormat) -> KvCacheConfig {
    let elem = FloatFormat::byte_width(format).unwrap_or(1);
    let mut c = KvCacheConfig::new(N_LAYERS, 64 * elem, format);
    c.page_tokens = 8;
    c
}

fn token_bytes(config: &KvCacheConfig, seed: u64) -> Vec<u8> {
    synthetic::kv_token_bytes(config, seed)
}

/// A pinned snapshot plus the shadow bytes at its capture instant: later
/// appends/evictions must never leak into its reads.
struct Held {
    snap: KvSnapshot,
    frozen: BTreeMap<usize, Vec<u8>>,
}

/// Every layer of a snapshot must match the given per-layer shadows, and
/// absent layers must error rather than fabricate bytes.
fn assert_snapshot_matches(snap: &KvSnapshot, shadows: &BTreeMap<usize, Vec<u8>>, ctx: &str) {
    for layer in 0..N_LAYERS {
        match shadows.get(&layer) {
            Some(shadow) => {
                assert_eq!(&snap.read(layer).unwrap(), shadow, "{ctx} layer {layer}");
            }
            None => assert!(snap.read(layer).is_err(), "{ctx} layer {layer}"),
        }
    }
}

/// Per-layer shadow slices for one sequence, cloned (the capture-time
/// freeze a `Held` snapshot is checked against).
fn freeze_seq(
    shadows: &BTreeMap<(u64, usize), Vec<u8>>,
    seq: u64,
) -> BTreeMap<usize, Vec<u8>> {
    (0..N_LAYERS)
        .filter_map(|layer| shadows.get(&(seq, layer)).map(|s| (layer, s.clone())))
        .collect()
}

/// Randomly interleave appends / snapshot reads / held-snapshot churn /
/// sequence evictions across ≥ 4 live sequences, asserting every read
/// against the shadow cache.
fn run_interleaved(format: FloatFormat, seed: u64) {
    let config = config_for(format);
    // Must cover the hot pages (10 lists x <= 2 KiB), one fully
    // materialized sequence (snapshots reside whole sequences), and up to
    // MAX_HELD stash-pinned sequences, while staying far enough below the
    // raw total that eviction runs constantly.
    let budget = 128 * 1024;
    let pool =
        SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
    let mut rng = Rng::new(seed);
    let mut shadows: BTreeMap<(u64, usize), Vec<u8>> = BTreeMap::new();
    let mut live: Vec<u64> = (1..=LIVE_SEQS as u64).collect();
    let mut next_seq = LIVE_SEQS as u64 + 1;
    let mut held: Vec<Held> = Vec::new();
    let mut reads = 0u64;
    let mut held_checks = 0u64;
    for step in 0..3000u64 {
        let op = rng.below(100);
        let seq = live[rng.below(live.len() as u64) as usize];
        let layer = rng.below(N_LAYERS as u64) as usize;
        if op < 60 {
            let kv = token_bytes(&config, step * 7919 + seq * 131 + layer as u64);
            pool.append_token(seq, layer, &kv).unwrap();
            shadows.entry((seq, layer)).or_default().extend_from_slice(&kv);
        } else if op < 81 {
            // Fresh snapshot: point-in-time view of the *current* shadows.
            match pool.snapshot(seq) {
                Ok(snap) => {
                    assert_snapshot_matches(&snap, &freeze_seq(&shadows, seq), "fresh");
                    reads += 1;
                }
                Err(_) => {
                    let has_data =
                        (0..N_LAYERS).any(|l| shadows.contains_key(&(seq, l)));
                    assert!(!has_data, "step {step}: snapshot refused a live seq {seq}");
                }
            }
        } else if op < 88 {
            // Pin a snapshot and hold it across future churn (drop-verify
            // the oldest first if the ring is full).
            if held.len() == MAX_HELD {
                let old = held.remove(0);
                assert_snapshot_matches(&old.snap, &old.frozen, "retiring held");
                held_checks += 1;
            }
            if let Ok(snap) = pool.snapshot(seq) {
                held.push(Held { snap, frozen: freeze_seq(&shadows, seq) });
            }
        } else if op < 97 {
            // Re-verify a random held snapshot mid-flight: appends, spills,
            // reloads, and evictions since capture must not show through.
            // Its clone must agree and share the pin.
            if !held.is_empty() {
                let h = &held[rng.below(held.len() as u64) as usize];
                let dup = h.snap.clone();
                assert_snapshot_matches(&h.snap, &h.frozen, "held");
                assert_snapshot_matches(&dup, &h.frozen, "held clone");
                held_checks += 1;
            }
        } else {
            // Retire one sequence, admit a fresh one (session churn). Held
            // snapshots of the retired sequence stay readable.
            pool.evict_sequence(seq);
            shadows.retain(|&(s, _), _| s != seq);
            live.retain(|&s| s != seq);
            live.push(next_seq);
            next_seq += 1;
        }
    }
    // Final sweep: everything still live must read back bit-exactly, and
    // every held snapshot must still serve its capture-time bytes.
    for seq in shadows.keys().map(|&(s, _)| s).collect::<std::collections::BTreeSet<_>>() {
        let snap = pool.snapshot(seq).unwrap();
        assert_snapshot_matches(&snap, &freeze_seq(&shadows, seq), "final");
    }
    for h in held.drain(..) {
        assert_snapshot_matches(&h.snap, &h.frozen, "final held");
        held_checks += 1;
    }
    let c = pool.counters();
    assert!(reads > 100, "schedule degenerate: only {reads} reads");
    assert!(held_checks > 20, "schedule degenerate: only {held_checks} held checks");
    assert!(c.spills > 0, "budget never forced a spill: {c}");
    assert!(c.reloads > 0, "no spill → reload round trip exercised: {c}");
    assert!(
        c.within_budget(),
        "schedule must never violate the budget, stash included: {c}"
    );
    // All pins are gone: the stash must be fully reclaimed and the epoch
    // clock no longer trailed.
    assert_eq!(c.stash_bytes, 0, "stash never drained: {c}");
    assert_eq!(c.epoch_lag, 0, "epoch lag with no live readers: {c}");
}

#[test]
fn prop_interleaved_ops_bit_exact_bf16() {
    run_interleaved(FloatFormat::Bf16, 11);
}

#[test]
fn prop_interleaved_ops_bit_exact_fp8_e4m3() {
    run_interleaved(FloatFormat::Fp8E4M3, 13);
}

#[test]
fn prop_concurrent_sequences_bit_exact() {
    // 8 big sequences on 4 threads sharing one budgeted pool: every thread
    // checks its own sequences against private shadows while eviction
    // steals pages across threads. Each thread additionally pins a small
    // dedicated sequence via a snapshot held for the whole run — its pages
    // are prime eviction victims (coldest in the LRU), so the evictor must
    // route them through the stash, keep them budget-charged, and the
    // held snapshot must stay bit-exact to the end.
    let config = config_for(FloatFormat::Bf16);
    let budget = 256 * 1024;
    let pool =
        SharedKvPool::new(PoolConfig::new(config.clone()).with_budget(budget)).unwrap();
    let n_threads = 4u64;
    let per_thread = 2u64;
    std::thread::scope(|scope| {
        for w in 0..n_threads {
            let pool = &pool;
            let config = &config;
            scope.spawn(move || {
                let seqs: Vec<u64> =
                    (0..per_thread).map(|i| 1 + w * per_thread + i).collect();
                // The small pinned sequence: 16 tokens (~4 KiB raw) on one
                // layer, snapshotted before the churn starts.
                let pin_seq = 100 + w;
                let mut pin_shadow: BTreeMap<(u64, usize), Vec<u8>> = BTreeMap::new();
                for t in 0..16u64 {
                    let kv = token_bytes(config, pin_seq * 999_983 + t);
                    pool.append_token(pin_seq, 0, &kv).unwrap();
                    pin_shadow.entry((pin_seq, 0)).or_default().extend_from_slice(&kv);
                }
                let held = Held {
                    snap: pool.snapshot(pin_seq).unwrap(),
                    frozen: freeze_seq(&pin_shadow, pin_seq),
                };
                let mut shadows: BTreeMap<(u64, usize), Vec<u8>> = BTreeMap::new();
                for t in 0..220u64 {
                    for &seq in &seqs {
                        for layer in 0..N_LAYERS {
                            let kv =
                                token_bytes(config, seq * 100_003 + t * 17 + layer as u64);
                            pool.append_token(seq, layer, &kv).unwrap();
                            shadows.entry((seq, layer)).or_default().extend_from_slice(&kv);
                        }
                    }
                    if t % 50 == 49 {
                        for &seq in &seqs {
                            let snap = pool.snapshot(seq).unwrap();
                            assert_snapshot_matches(
                                &snap,
                                &freeze_seq(&shadows, seq),
                                "round",
                            );
                        }
                        // The pinned snapshot must read its capture-time
                        // bytes no matter what the churn evicted meanwhile.
                        assert_snapshot_matches(&held.snap, &held.frozen, "held round");
                    }
                }
                assert_snapshot_matches(&held.snap, &held.frozen, "held final");
                for &seq in &seqs {
                    let snap = pool.snapshot(seq).unwrap();
                    assert_snapshot_matches(&snap, &freeze_seq(&shadows, seq), "final");
                }
            });
        }
    });
    let c = pool.counters();
    assert!(c.spills > 0, "concurrent scenario never spilled: {c}");
    assert!(c.reloads > 0, "concurrent scenario never reloaded: {c}");
    assert!(c.within_budget(), "budget violated under concurrent readers: {c}");
    assert_eq!(c.stash_bytes, 0, "stash never drained: {c}");
    // 8 seqs x 2 layers x 220 tokens x 256 B = 880 KiB raw >> 256 KiB.
    assert!(pool.stats().raw_bytes > budget);
}
