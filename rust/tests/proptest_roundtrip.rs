//! Property tests: lossless round-trip of every codec path under randomized
//! inputs.
//!
//! The baked registry has no proptest crate, so this file uses an in-house
//! harness: seeded generation via `zipnn_lp::util::rng::Rng` over many
//! cases per property. Failures print the seed so cases replay exactly.

use zipnn_lp::baselines;
use zipnn_lp::codec::{
    compress_delta, compress_mxfp4, compress_nvfp4, compress_tensor, decompress_chunk,
    decompress_delta, decompress_mxfp4, decompress_nvfp4, decompress_tensor, Codec,
    CompressOptions, CompressedBlob,
};
use zipnn_lp::formats::conv::{quantize_mxfp4, quantize_nvfp4};
use zipnn_lp::formats::{merge_streams, split_streams, FloatFormat};
use zipnn_lp::util::rng::Rng;

const FORMATS: [FloatFormat; 6] = [
    FloatFormat::Fp32,
    FloatFormat::Fp16,
    FloatFormat::Bf16,
    FloatFormat::Fp8E4M3,
    FloatFormat::Fp8E5M2,
    FloatFormat::Fp4E2M1,
];

fn align(format: FloatFormat) -> usize {
    match format {
        FloatFormat::Fp32 => 4,
        FloatFormat::Fp16 | FloatFormat::Bf16 | FloatFormat::Fp8E4M3 | FloatFormat::Fp4E2M1 => 2,
        FloatFormat::Fp8E5M2 => 1,
    }
}

/// Byte buffers spanning the interesting distributions: uniform noise,
/// constant, skewed symbols, Gaussian-weight-like, sparse-delta-like.
fn gen_case(rng: &mut Rng, format: FloatFormat) -> Vec<u8> {
    let a = align(format);
    let len = (rng.below(40_000) as usize + 1) / a * a;
    match rng.below(5) {
        0 => {
            let mut v = vec![0u8; len];
            rng.fill_bytes(&mut v);
            v
        }
        1 => vec![(rng.below(256)) as u8; len],
        2 => (0..len)
            .map(|_| if rng.next_f64() < 0.9 { 0x3F } else { rng.below(256) as u8 })
            .collect(),
        3 => zipnn_lp::synthetic::gaussian_bf16_bytes(len / 2, 0.05, rng.next_u64())
            .into_iter()
            .take(len / a * a)
            .collect(),
        _ => {
            // Sparse: mostly zero with random islands (XOR-delta-like).
            let mut v = vec![0u8; len];
            for _ in 0..len / 50 {
                let i = rng.below(len.max(1) as u64) as usize;
                v[i] = rng.below(256) as u8;
            }
            v
        }
    }
}

#[test]
fn prop_split_merge_is_bijective() {
    let mut rng = Rng::new(0xABCD);
    for case in 0..200 {
        let format = FORMATS[(case % FORMATS.len()) as usize];
        let data = gen_case(&mut rng, format);
        let set = split_streams(format, &data)
            .unwrap_or_else(|e| panic!("case {case} {format:?}: split failed: {e}"));
        let native: u64 = set.streams.iter().map(|s| s.native_size_bits()).sum();
        assert_eq!(native, data.len() as u64 * 8, "case {case} {format:?}: bits conserved");
        let back = merge_streams(format, &set).unwrap();
        assert_eq!(back, data, "case {case} {format:?}");
    }
}

#[test]
fn prop_compress_roundtrip_all_formats() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..150 {
        let format = FORMATS[(case % FORMATS.len()) as usize];
        let data = gen_case(&mut rng, format);
        let chunk = 512 + rng.below(8192) as usize;
        let mut opts = CompressOptions::for_format(format).with_chunk_size(chunk);
        opts.len_limit = 8 + (rng.below(8)) as u8;
        let blob = compress_tensor(&data, &opts)
            .unwrap_or_else(|e| panic!("case {case} {format:?}: {e}"));
        let back = decompress_tensor(&blob).unwrap();
        assert_eq!(back, data, "case {case} {format:?} chunk={chunk}");
        // Serialized form round-trips too.
        let blob2 =
            zipnn_lp::codec::CompressedBlob::deserialize(&blob.serialize()).unwrap();
        assert_eq!(decompress_tensor(&blob2).unwrap(), data, "case {case} serialized");
    }
}

#[test]
fn prop_cross_codec_roundtrip_all_formats() {
    // Every format × every backend policy round-trips bit-exactly, both
    // in-memory and through blob (de)serialization; and auto's blob is
    // never larger than the best fixed backend's.
    let mut rng = Rng::new(0xC0DEC);
    let codecs = [Codec::Auto, Codec::Huffman, Codec::Rans, Codec::Raw];
    for case in 0..60 {
        let format = FORMATS[case % FORMATS.len()];
        let data = gen_case(&mut rng, format);
        let chunk = 512 + rng.below(8192) as usize;
        let mut sizes = std::collections::BTreeMap::new();
        for codec in codecs {
            let opts = CompressOptions::for_format(format)
                .with_chunk_size(chunk)
                .with_codec(codec);
            let blob = compress_tensor(&data, &opts)
                .unwrap_or_else(|e| panic!("case {case} {format:?} {codec:?}: {e}"));
            assert_eq!(blob.codec, codec);
            assert_eq!(
                decompress_tensor(&blob).unwrap(),
                data,
                "case {case} {format:?} {codec:?}"
            );
            let ser = blob.serialize();
            let blob2 = CompressedBlob::deserialize(&ser).unwrap();
            assert_eq!(blob2.codec, codec);
            assert_eq!(
                decompress_tensor(&blob2).unwrap(),
                data,
                "case {case} {format:?} {codec:?} serialized"
            );
            sizes.insert(codec.name(), ser.len());
        }
        let auto = sizes["auto"];
        let best = *sizes
            .iter()
            .filter(|(&k, _)| k != "auto")
            .map(|(_, v)| v)
            .min()
            .unwrap();
        assert!(
            auto <= best,
            "case {case} {format:?}: auto blob {auto} B > best fixed {best} B ({sizes:?})"
        );
    }
}

#[test]
fn prop_v1_blobs_still_decode() {
    // Wire compat: a v1 blob is the v2 blob minus the codec byte. Huffman
    // chunks are unchanged between versions, so rewriting the header of a
    // Huffman-coded v2 blob produces a faithful v1 blob — it must parse,
    // report the implicit Huffman codec, and decode bit-exactly.
    let mut rng = Rng::new(0x0111);
    for case in 0..40 {
        let format = FORMATS[case % FORMATS.len()];
        let data = gen_case(&mut rng, format);
        let opts = CompressOptions::for_format(format)
            .with_chunk_size(2048)
            .with_codec(Codec::Huffman);
        let blob = compress_tensor(&data, &opts).unwrap();
        let mut v1 = blob.serialize();
        v1.remove(8); // drop the codec byte
        v1[4..6].copy_from_slice(&1u16.to_le_bytes());
        let parsed = CompressedBlob::deserialize(&v1)
            .unwrap_or_else(|e| panic!("case {case} {format:?}: v1 parse failed: {e}"));
        assert_eq!(parsed.codec, Codec::Huffman, "case {case}");
        assert_eq!(decompress_tensor(&parsed).unwrap(), data, "case {case} {format:?}");
    }
}

#[test]
fn prop_corrupted_rans_streams_never_pass_silently() {
    // Same discipline as the Huffman corruption property, pinned to the
    // rANS backend: a flipped payload bit must either fail (frame parse,
    // coder invariants, or chunk CRC) or decode to the original bytes
    // (dead-padding hits) — never to silently different data.
    let mut rng = Rng::new(0xBADA5);
    let mut detected = 0;
    let cases = 60;
    for case in 0..cases {
        let data = gen_case(&mut rng, FloatFormat::Fp8E4M3);
        if data.is_empty() {
            continue;
        }
        let opts = CompressOptions::for_format(FloatFormat::Fp8E4M3)
            .with_chunk_size(4096)
            .with_codec(Codec::Rans);
        let mut blob = compress_tensor(&data, &opts).unwrap();
        if blob.data.is_empty() {
            continue;
        }
        let byte = rng.below(blob.data.len() as u64) as usize;
        let bit = 1u8 << rng.below(8);
        blob.data[byte] ^= bit;
        match decompress_tensor(&blob) {
            Err(_) => detected += 1,
            Ok(out) => {
                assert_eq!(out, data, "case {case}: silent corruption passed the CRC");
            }
        }
    }
    assert!(detected >= cases * 9 / 10, "only {detected}/{cases} detected");
}

#[test]
fn prop_random_access_equals_full_decode() {
    let mut rng = Rng::new(0xCAFE);
    for case in 0..50 {
        let data = gen_case(&mut rng, FloatFormat::Bf16);
        let opts = CompressOptions::for_format(FloatFormat::Bf16).with_chunk_size(2048);
        let blob = compress_tensor(&data, &opts).unwrap();
        let full = decompress_tensor(&blob).unwrap();
        let mut stitched = Vec::new();
        for i in 0..blob.chunks.len() {
            stitched.extend(decompress_chunk(&blob, i).unwrap());
        }
        assert_eq!(stitched, full, "case {case}");
    }
}

#[test]
fn prop_delta_roundtrip() {
    let mut rng = Rng::new(0xD417A);
    for case in 0..60 {
        let n = (rng.below(30_000) as usize + 2) / 2 * 2;
        let base = gen_case(&mut rng, FloatFormat::Bf16)
            .into_iter()
            .take(n)
            .chain(std::iter::repeat(0))
            .take(n)
            .collect::<Vec<u8>>();
        let current = zipnn_lp::synthetic::perturb_bf16_bytes(
            &base,
            0.01,
            rng.next_f64(),
            rng.next_u64(),
        );
        let opts = CompressOptions::for_format(FloatFormat::Bf16).with_chunk_size(4096);
        let blob = compress_delta(&current, &base, &opts).unwrap();
        assert_eq!(decompress_delta(&blob, &base).unwrap(), current, "case {case}");
    }
}

#[test]
fn prop_corruption_never_passes_silently() {
    // Flip one random payload bit: decode must either error (framing / CRC)
    // or — when the flip lands in dead bits such as the zero padding of a
    // Huffman payload's final byte — still reproduce the original data
    // exactly. What must NEVER happen is a successful decode of *different*
    // data: that would be silent corruption slipping through a valid CRC.
    let mut rng = Rng::new(0x0BAD);
    let mut detected = 0;
    let cases = 60;
    for case in 0..cases {
        let data = gen_case(&mut rng, FloatFormat::Bf16);
        if data.is_empty() {
            continue;
        }
        let opts = CompressOptions::for_format(FloatFormat::Bf16).with_chunk_size(4096);
        let mut blob = compress_tensor(&data, &opts).unwrap();
        if blob.data.is_empty() {
            continue;
        }
        let byte = rng.below(blob.data.len() as u64) as usize;
        let bit = 1u8 << rng.below(8);
        blob.data[byte] ^= bit;
        match decompress_tensor(&blob) {
            Err(_) => detected += 1,
            Ok(out) => {
                assert_eq!(out, data, "case {case}: silent corruption passed the CRC");
            }
        }
    }
    // CRC32 + framing catch essentially every flip; only dead-padding hits
    // (a handful of bits per stream) can decode cleanly.
    assert!(detected >= cases * 9 / 10, "only {detected}/{cases} detected");
}

#[test]
fn prop_nvfp4_block_roundtrip() {
    let mut rng = Rng::new(0xF4);
    for case in 0..40 {
        let n = (rng.below(5_000) as usize + 16) / 16 * 16;
        let vals: Vec<f32> = (0..n)
            .map(|_| (rng.normal_ms(0.0, 0.5)) as f32)
            .collect();
        let t = quantize_nvfp4(&vals);
        let opts = CompressOptions::for_format(FloatFormat::Fp4E2M1);
        let blob = compress_nvfp4(&t, &opts).unwrap();
        assert_eq!(decompress_nvfp4(&blob).unwrap(), t, "case {case}");
    }
}

#[test]
fn prop_mxfp4_block_roundtrip() {
    let mut rng = Rng::new(0xF5);
    for case in 0..40 {
        let n = rng.below(5_000) as usize + 1;
        let group = [32usize, 48, 64][(case % 3) as usize];
        let sf = if case % 2 == 0 { FloatFormat::Fp16 } else { FloatFormat::Fp32 };
        let vals: Vec<f32> = (0..n).map(|_| (rng.normal_ms(0.0, 2.0)) as f32).collect();
        let t = quantize_mxfp4(&vals, group, sf).unwrap();
        let opts = CompressOptions::for_format(FloatFormat::Fp4E2M1);
        let blob = compress_mxfp4(&t, &opts).unwrap();
        assert_eq!(decompress_mxfp4(&blob).unwrap(), t, "case {case}");
    }
}

#[test]
fn prop_baselines_roundtrip() {
    let mut rng = Rng::new(0xBA5E);
    for case in 0..60 {
        let data = gen_case(&mut rng, FloatFormat::Bf16);
        let b = baselines::byte_huffman(&data).unwrap();
        assert_eq!(baselines::byte_huffman_decode(&b).unwrap(), data, "bh case {case}");
        let r = baselines::rle(&data);
        assert_eq!(baselines::rle_decode(&r).unwrap(), data, "rle case {case}");
        let l = baselines::lzss_huffman(&data).unwrap();
        assert_eq!(baselines::lzss_huffman_decode(&l).unwrap(), data, "lzss case {case}");
    }
}

#[test]
fn prop_threads_do_not_change_output() {
    let mut rng = Rng::new(0x7124D5);
    for case in 0..20 {
        let data = gen_case(&mut rng, FloatFormat::Fp8E4M3);
        let base = CompressOptions::for_format(FloatFormat::Fp8E4M3).with_chunk_size(1024);
        let a = compress_tensor(&data, &base).unwrap();
        let b = compress_tensor(&data, &base.clone().with_threads(3)).unwrap();
        assert_eq!(a.serialize(), b.serialize(), "case {case}");
    }
}
