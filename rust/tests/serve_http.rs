//! End-to-end tests for the model-distribution server (`zipnn_lp::serve`)
//! over real loopback sockets: full and ranged pulls, the resume protocol
//! (`ETag` + `If-Range`), protocol-error responses (400/408/416/431/503),
//! and the robustness contract — a client vanishing mid-stream must not
//! poison the worker pool.
//!
//! The HTTP parser's unit tests live in `src/serve/http.rs`; everything
//! here goes through a `TcpStream` so the deadline/limit handling and the
//! response framing are exercised for real.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use zipnn_lp::codec::{compress_tensor, CompressOptions};
use zipnn_lp::container::{Archive, ReadBacking, TensorMeta};
use zipnn_lp::formats::FloatFormat;
use zipnn_lp::serve::{serve, ModelRegistry, ServeOptions, ServerHandle};
use zipnn_lp::synthetic;
use zipnn_lp::util::json::Json;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("zipnn_lp_itest_serve")
        .join(format!("{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a v2 archive with `elems` BF16 values and return its raw file
/// bytes — the ground truth every pull is compared against.
fn write_archive(path: &Path, elems: usize, seed: u64) -> Vec<u8> {
    let data = synthetic::gaussian_bf16_bytes(elems, 0.02, seed);
    let blob = compress_tensor(&data, &CompressOptions::for_format(FloatFormat::Bf16)).unwrap();
    let mut archive = Archive::new();
    archive.insert(TensorMeta { name: "data".into(), shape: vec![elems as u64] }, blob);
    archive.save(path).unwrap();
    std::fs::read(path).unwrap()
}

/// Start a server over a fresh one-archive directory; returns the ground
/// truth bytes too. Callers own the handle (drop stops the server).
fn start(tag: &str, elems: usize, opts: ServeOptions) -> (ServerHandle, Vec<u8>, PathBuf) {
    let dir = tmpdir(tag);
    let file = write_archive(&dir.join("m.zlp"), elems, 7);
    let registry = ModelRegistry::open_dir(&dir, ReadBacking::Auto).unwrap();
    let server = serve(registry, &opts).unwrap();
    (server, file, dir)
}

struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

fn parse_response(raw: &[u8]) -> Response {
    let pos = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response head terminator");
    let head = std::str::from_utf8(&raw[..pos]).unwrap();
    let mut lines = head.split("\r\n");
    let status: u16 = lines
        .next()
        .unwrap()
        .split(' ')
        .nth(1)
        .expect("status code")
        .parse()
        .unwrap();
    let headers = lines
        .map(|line| {
            let (k, v) = line.split_once(':').expect("header colon");
            (k.to_ascii_lowercase(), v.trim().to_string())
        })
        .collect();
    Response { status, headers, body: raw[pos + 4..].to_vec() }
}

/// One request → full response (the server always closes after one).
fn request(addr: SocketAddr, raw: &str) -> Response {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut out = Vec::new();
    stream.read_to_end(&mut out).unwrap();
    parse_response(&out)
}

fn get(addr: SocketAddr, target: &str, extra: &str) -> Response {
    request(addr, &format!("GET {target} HTTP/1.1\r\nhost: t\r\n{extra}\r\n"))
}

#[test]
fn full_and_head_pulls_are_bit_exact() {
    let (server, file, dir) = start("full", 4000, ServeOptions::default());
    let addr = server.addr();

    let full = get(addr, "/models/m.zlp", "");
    assert_eq!(full.status, 200);
    assert_eq!(full.body, file, "full pull must be bit-exact");
    assert_eq!(full.header("content-length"), Some(file.len().to_string().as_str()));
    assert_eq!(full.header("accept-ranges"), Some("bytes"));
    let etag = full.header("etag").expect("model responses carry an ETag").to_string();
    assert!(etag.starts_with("\"zlps-"), "strong quoted validator, got {etag}");

    let head = request(addr, "HEAD /models/m.zlp HTTP/1.1\r\nhost: t\r\n\r\n");
    assert_eq!(head.status, 200);
    assert!(head.body.is_empty(), "HEAD must not carry a body");
    assert_eq!(head.header("content-length"), Some(file.len().to_string().as_str()));
    assert_eq!(head.header("etag"), Some(etag.as_str()));
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn range_semantics_cover_206_416_and_fallbacks() {
    let (server, file, dir) = start("ranges", 4000, ServeOptions::default());
    let addr = server.addr();
    let total = file.len();

    // Closed range and open-ended suffix both return exactly the slice.
    let mid = get(addr, "/models/m.zlp", "range: bytes=100-299\r\n");
    assert_eq!(mid.status, 206);
    assert_eq!(mid.body, &file[100..300]);
    assert_eq!(
        mid.header("content-range"),
        Some(format!("bytes 100-299/{total}").as_str())
    );
    let tail = get(addr, "/models/m.zlp", &format!("range: bytes={}-\r\n", total - 64));
    assert_eq!(tail.status, 206);
    assert_eq!(tail.body, &file[total - 64..]);
    let suffix = get(addr, "/models/m.zlp", "range: bytes=-32\r\n");
    assert_eq!(suffix.status, 206);
    assert_eq!(suffix.body, &file[total - 32..]);

    // Start past EOF and an empty suffix are unsatisfiable: 416 with the
    // total advertised so the client can retry sensibly.
    for bad in [format!("range: bytes={total}-\r\n"), "range: bytes=-0\r\n".to_string()] {
        let r = get(addr, "/models/m.zlp", &bad);
        assert_eq!(r.status, 416, "expected 416 for {bad:?}");
        assert_eq!(r.header("content-range"), Some(format!("bytes */{total}").as_str()));
        assert!(r.body.is_empty());
    }

    // Multi-range and syntactic junk fall back to the full body (RFC 9110
    // lets a server ignore Range) — never an error, never a short read.
    for fallback in ["range: bytes=0-1,3-4\r\n", "range: bytes=abc\r\n", "range: elephants=0-1\r\n"]
    {
        let r = get(addr, "/models/m.zlp", fallback);
        assert_eq!(r.status, 200, "expected full-body fallback for {fallback:?}");
        assert_eq!(r.body, file);
    }
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn interrupted_pull_resumes_bit_exactly_via_if_range() {
    let (server, file, dir) = start("resume", 60_000, ServeOptions::default());
    let addr = server.addr();

    // Pull the whole model but sever the connection after ~16 KiB of body:
    // a genuine mid-transfer interruption, not a polite ranged request.
    let keep = 16 * 1024;
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(b"GET /models/m.zlp HTTP/1.1\r\nhost: t\r\n\r\n")
        .unwrap();
    let mut raw = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        let got = stream.read(&mut chunk).unwrap();
        assert!(got > 0, "server closed before the interruption point");
        raw.extend_from_slice(&chunk[..got]);
        let head_end = raw.windows(4).position(|w| w == b"\r\n\r\n");
        if head_end.is_some_and(|pos| raw.len() - (pos + 4) >= keep) {
            break;
        }
    }
    drop(stream); // interrupt mid-stream
    let first = parse_response(&raw);
    assert_eq!(first.status, 200);
    let etag = first.header("etag").unwrap().to_string();
    let mut assembled = first.body[..keep].to_vec();

    // Resume from where it broke, conditioned on the validator. Fresh ETag
    // → 206 continuation; append and the result must be the archive.
    let resume = get(
        addr,
        "/models/m.zlp",
        &format!("range: bytes={keep}-\r\nif-range: {etag}\r\n"),
    );
    assert_eq!(resume.status, 206);
    assembled.extend_from_slice(&resume.body);
    assert_eq!(assembled, file, "interrupted-and-resumed pull must be bit-exact");

    // A stale validator must NOT be spliced: the server downgrades to the
    // full body so the client rebuilds from scratch.
    let stale = get(
        addr,
        "/models/m.zlp",
        &format!("range: bytes={keep}-\r\nif-range: \"zlps-00000000-0\"\r\n"),
    );
    assert_eq!(stale.status, 200);
    assert_eq!(stale.body, file);
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_and_model_list_parse_and_match_the_file() {
    let (server, file, dir) = start("manifest", 4000, ServeOptions::default());
    let addr = server.addr();
    let etag = get(addr, "/models/m.zlp", "").header("etag").unwrap().to_string();

    let manifest = get(addr, "/models/m.zlp/manifest", "");
    assert_eq!(manifest.status, 200);
    assert_eq!(manifest.header("content-type"), Some("application/json"));
    let doc = Json::parse(std::str::from_utf8(&manifest.body).unwrap()).unwrap();
    assert_eq!(doc.field("name").unwrap().as_str(), Some("m.zlp"));
    assert_eq!(doc.field("etag").unwrap().as_str(), Some(etag.as_str()));
    assert_eq!(doc.field("file_len").unwrap().as_usize(), Some(file.len()));
    assert_eq!(doc.field("version").unwrap().as_usize(), Some(2));
    let tensors = doc.field("tensors").unwrap().as_arr().unwrap();
    assert_eq!(tensors.len(), 1);
    let t = &tensors[0];
    assert_eq!(t.field("name").unwrap().as_str(), Some("data"));
    assert!(t.field("n_chunks").unwrap().as_usize().unwrap() >= 1);
    // The advertised chunk region must lie inside the served file — that is
    // what makes chunk-aligned parallel range pulls schedulable.
    let off = t.field("data_offset").unwrap().as_usize().unwrap();
    let len = t.field("data_len").unwrap().as_usize().unwrap();
    assert!(off + len <= file.len(), "chunk region {off}+{len} exceeds {}", file.len());

    let list = get(addr, "/models", "");
    assert_eq!(list.status, 200);
    let doc = Json::parse(std::str::from_utf8(&list.body).unwrap()).unwrap();
    let models = doc.field("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].field("name").unwrap().as_str(), Some("m.zlp"));
    assert_eq!(models[0].field("etag").unwrap().as_str(), Some(etag.as_str()));
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn protocol_errors_get_typed_responses() {
    let opts = ServeOptions { header_timeout: Duration::from_millis(300), ..Default::default() };
    let (server, _file, dir) = start("errors", 2000, opts);
    let addr = server.addr();

    // Malformed request line → 400.
    assert_eq!(request(addr, "NOTAREQUEST\r\n\r\n").status, 400);
    assert_eq!(request(addr, "get /models HTTP/1.1\r\n\r\n").status, 400);
    // Declared body → 400 (this server serves, it does not ingest).
    assert_eq!(
        request(addr, "GET /models HTTP/1.1\r\ncontent-length: 3\r\n\r\nabc").status,
        400
    );
    // Unsupported method → 405 with Allow.
    let post = request(addr, "POST /models/m.zlp HTTP/1.1\r\n\r\n");
    assert_eq!(post.status, 405);
    assert_eq!(post.header("allow"), Some("GET, HEAD"));
    // Unknown route / unknown model → 404.
    assert_eq!(get(addr, "/elsewhere", "").status, 404);
    assert_eq!(get(addr, "/models/ghost", "").status, 404);

    // Slow loris: an unterminated head past the deadline → 408.
    let mut slow = TcpStream::connect(addr).unwrap();
    slow.write_all(b"GET /models/m.zlp HTTP/1.1\r\nx-slow: yes").unwrap();
    let mut out = Vec::new();
    slow.read_to_end(&mut out).unwrap();
    assert_eq!(parse_response(&out).status, 408);

    // Oversized head → 431 without waiting for a terminator.
    let mut big = TcpStream::connect(addr).unwrap();
    big.write_all(b"GET /models/m.zlp HTTP/1.1\r\n").unwrap();
    let filler = format!("x-filler: {}\r\n", "a".repeat(1000));
    for _ in 0..20 {
        if big.write_all(filler.as_bytes()).is_err() {
            break; // server already answered and closed; fine
        }
    }
    let mut out = Vec::new();
    big.read_to_end(&mut out).unwrap();
    assert_eq!(parse_response(&out).status, 431);
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mid_stream_disconnect_does_not_poison_the_pool() {
    // Large enough that the server cannot fit the whole body into socket
    // buffers: the client's early close surfaces as a write error inside
    // the streaming loop, on a worker thread.
    let (server, file, dir) = start("disconnect", 1_500_000, ServeOptions::default());
    let addr = server.addr();

    for _ in 0..3 {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"GET /models/m.zlp HTTP/1.1\r\nhost: t\r\n\r\n")
            .unwrap();
        let mut chunk = [0u8; 8192];
        let got = stream.read(&mut chunk).unwrap();
        assert!(got > 0);
        drop(stream); // vanish with most of the body unsent
    }
    // Every worker that served a vanished client must have released its
    // slot: a full pull still succeeds and is still bit-exact.
    let full = get(addr, "/models/m.zlp", "");
    assert_eq!(full.status, 200);
    assert_eq!(full.body, file);
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn connection_cap_answers_503_and_recovers() {
    let opts = ServeOptions { workers: 1, max_conns: 1, ..Default::default() };
    let (server, file, dir) = start("cap", 2000, opts);
    let addr = server.addr();

    // Occupy the single slot with a deliberately unfinished request head
    // (the handler sits in its read deadline), then probe: the next
    // connection must be rejected immediately with 503, not queued.
    let mut holder = TcpStream::connect(addr).unwrap();
    holder.write_all(b"GET /models/m.zlp HTTP/1.1\r\n").unwrap();
    let busy = get(addr, "/models/m.zlp", "");
    assert_eq!(busy.status, 503);
    assert_eq!(busy.header("retry-after"), Some("1"));

    // Release the slot; the server must recover to full service. The
    // handler notices the close on its next buffered read.
    drop(holder);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let r = get(addr, "/models/m.zlp", "");
        if r.status == 200 {
            assert_eq!(r.body, file);
            break;
        }
        assert_eq!(r.status, 503, "only busy rejections expected while draining");
        assert!(std::time::Instant::now() < deadline, "slot never released");
        std::thread::sleep(Duration::from_millis(50));
    }
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn metrics_endpoint_reports_serve_counters() {
    let (server, _file, dir) = start("metrics", 2000, ServeOptions::default());
    let addr = server.addr();
    assert_eq!(get(addr, "/models/m.zlp", "").status, 200);

    let metrics = get(addr, "/metrics", "");
    assert_eq!(metrics.status, 200);
    let text = String::from_utf8(metrics.body).unwrap();
    for needle in [
        "zipnn_serve_requests_model_total",
        "zipnn_serve_bytes_sent_total",
        "zipnn_serve_inflight_connections",
    ] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
    drop(server);
    std::fs::remove_dir_all(&dir).ok();
}
