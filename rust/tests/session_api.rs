//! Integration tests for the session-based codec API: `Compressor`
//! streaming with bounded buffering, zero-copy decode error paths, and the
//! random-access archive v2 (plus v1 back-compat).
//!
//! Like the other test targets, this file uses the in-house seeded property
//! harness (`zipnn_lp::util::rng::Rng`) instead of a proptest crate.

use zipnn_lp::codec::{compress_tensor, CompressOptions, Compressor, TensorInput};
use zipnn_lp::container::{Archive, ArchiveReader, ArchiveWriter, TensorMeta};
use zipnn_lp::formats::FloatFormat;
use zipnn_lp::synthetic;
use zipnn_lp::util::rng::Rng;
use std::path::PathBuf;

const FORMATS: [FloatFormat; 5] = [
    FloatFormat::Fp32,
    FloatFormat::Fp16,
    FloatFormat::Bf16,
    FloatFormat::Fp8E4M3,
    FloatFormat::Fp8E5M2,
];

fn align(format: FloatFormat) -> usize {
    match format {
        FloatFormat::Fp32 => 4,
        FloatFormat::Fp16 | FloatFormat::Bf16 | FloatFormat::Fp8E4M3 => 2,
        _ => 1,
    }
}

fn tmppath(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("zipnn_lp_session_api");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}_{}.zlp", std::process::id()))
}

/// Acceptance: a tensor several times larger than the streaming window
/// moves through compress_stream/decompress_stream bit-exactly, with the
/// in-flight footprint bounded by the window, not the tensor.
#[test]
fn streaming_bounded_buffering_far_beyond_window() {
    let chunk = 8 * 1024;
    let threads = 2;
    let session = Compressor::new(
        CompressOptions::for_format(FloatFormat::Bf16)
            .with_chunk_size(chunk)
            .with_threads(threads),
    );
    // 2 MiB of data against a 16 KiB window: 128x larger.
    let data = synthetic::gaussian_bf16_bytes(1024 * 1024, 0.02, 71);
    let mut wire = Vec::new();
    let summary = session.compress_stream(&data[..], &mut wire).unwrap();
    assert_eq!(summary.original_len, data.len() as u64);
    assert_eq!(summary.encoded_len, wire.len() as u64);
    let window = (threads * summary.chunk_size) as u64;
    assert!(
        summary.peak_buffered <= 2 * window + 16 * 1024,
        "encode peak {} not bounded by window {window}",
        summary.peak_buffered
    );
    assert!(
        summary.peak_buffered < data.len() as u64 / 16,
        "encode peak {} scales with the stream, not the window",
        summary.peak_buffered
    );
    let mut out = Vec::new();
    let dsum = session.decompress_stream(&wire[..], &mut out).unwrap();
    assert_eq!(out, data, "stream roundtrip must be bit-exact");
    assert_eq!(dsum.chunks, summary.chunks);
    assert!(
        dsum.peak_buffered <= 2 * window + 16 * 1024,
        "decode peak {} not bounded by window {window}",
        dsum.peak_buffered
    );
}

/// Property: streaming output carries exactly the buffered encoder's chunk
/// payloads for the same options, across all five scalar formats.
#[test]
fn prop_streaming_matches_buffered_all_formats() {
    let mut rng = Rng::new(2024);
    for format in FORMATS {
        for case in 0..6 {
            let a = align(format);
            let len = (1 + rng.below(60_000) as usize) / a * a;
            let mut data = vec![0u8; len];
            match case % 3 {
                0 => rng.fill_bytes(&mut data),
                1 => data.fill(0x41),
                _ => {
                    for b in data.iter_mut() {
                        *b = if rng.next_f64() < 0.85 { 0x3E } else { rng.below(256) as u8 };
                    }
                }
            }
            let session = Compressor::new(
                CompressOptions::for_format(format)
                    .with_chunk_size(4096)
                    .with_threads(1 + (case % 3)),
            );
            let blob = session.compress(TensorInput::Tensor(&data)).unwrap();
            let mut wire = Vec::new();
            session.compress_stream(&data[..], &mut wire).unwrap();
            // The streamed chunk payloads, concatenated, are the blob's
            // data region, bit for bit.
            let concat = extract_stream_chunks(&wire);
            assert_eq!(concat, blob.data, "{format:?} case {case}");
            let mut round = Vec::new();
            session.decompress_stream(&wire[..], &mut round).unwrap();
            assert_eq!(round, data, "{format:?} case {case} roundtrip");
        }
    }
}

/// Pull the concatenated encoded chunk payloads out of a ZLPS stream.
fn extract_stream_chunks(wire: &[u8]) -> Vec<u8> {
    use zipnn_lp::util::varint;
    let mut pos = 9usize; // magic + version + strategy/format/codec
    let _chunk_size = varint::read_usize(wire, &mut pos).unwrap();
    let mut out = Vec::new();
    loop {
        let marker = wire[pos];
        pos += 1;
        if marker == 0 {
            break;
        }
        let _raw_len = varint::read_usize(wire, &mut pos).unwrap();
        pos += 4; // crc
        let enc_len = varint::read_usize(wire, &mut pos).unwrap();
        out.extend_from_slice(&wire[pos..pos + enc_len]);
        pos += enc_len;
    }
    out
}

/// decompress_into / decompress_chunk_into refuse wrong-size buffers with
/// InvalidInput, and succeed on exact ones.
#[test]
fn decompress_into_length_mismatches() {
    let session = Compressor::new(
        CompressOptions::for_format(FloatFormat::Fp8E4M3).with_chunk_size(2048),
    );
    let mut rng = Rng::new(5);
    let mut data = vec![0u8; 10_000];
    rng.fill_bytes(&mut data);
    let blob = session.compress(TensorInput::Tensor(&data)).unwrap();
    for bad in [0usize, 1, data.len() - 1, data.len() + 1] {
        let mut out = vec![0u8; bad];
        let err = session.decompress_into(&blob, &mut out).unwrap_err();
        assert!(
            matches!(err, zipnn_lp::Error::InvalidInput(_)),
            "len {bad}: {err}"
        );
    }
    let mut out = vec![0u8; data.len()];
    session.decompress_into(&blob, &mut out).unwrap();
    assert_eq!(out, data);
    // Chunk-level.
    let raw0 = blob.chunks[0].raw_len;
    let mut bad = vec![0u8; raw0 + 1];
    assert!(session.decompress_chunk_into(&blob, 0, &mut bad).is_err());
    let mut ok = vec![0u8; raw0];
    session.decompress_chunk_into(&blob, 0, &mut ok).unwrap();
    assert_eq!(ok[..], data[..raw0]);
}

/// Property: archive v2 round-trips arbitrary tensor sets through the
/// incremental writer and the positioned reader; a v1 file written from
/// the same tensors still decodes identically.
#[test]
fn prop_archive_v2_roundtrip_and_v1_backcompat() {
    let mut rng = Rng::new(88);
    for case in 0..8 {
        let n_tensors = 1 + rng.below(5) as usize;
        let mut tensors: Vec<(String, Vec<u8>, FloatFormat)> = Vec::new();
        for i in 0..n_tensors {
            let format = FORMATS[rng.below(FORMATS.len() as u64) as usize];
            let a = align(format);
            let len = (1 + rng.below(30_000) as usize) / a * a;
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            tensors.push((format!("case{case}.t{i}"), data, format));
        }

        // v2 via the incremental writer.
        let path = tmppath(&format!("prop_v2_{case}"));
        let mut writer = ArchiveWriter::create(&path).unwrap();
        let mut archive = Archive::new(); // shadow for the v1 file
        for (name, data, format) in &tensors {
            let session = Compressor::new(
                CompressOptions::for_format(*format).with_chunk_size(4096),
            );
            let blob = session.compress(TensorInput::Tensor(data)).unwrap();
            writer
                .add(TensorMeta { name: name.clone(), shape: vec![data.len() as u64] }, &blob)
                .unwrap();
            archive.insert(
                TensorMeta { name: name.clone(), shape: vec![data.len() as u64] },
                blob,
            );
        }
        writer.finish().unwrap();
        let reader = ArchiveReader::open(&path).unwrap();
        assert_eq!(reader.len(), tensors.len(), "case {case}");
        for (name, data, format) in &tensors {
            assert_eq!(&reader.read_tensor(name).unwrap(), data, "case {case} {name}");
            let entry = reader.entry(name).unwrap();
            assert_eq!(entry.format, *format);
            assert_eq!(entry.original_len, data.len());
            // Random chunk + random byte range.
            if !entry.chunks.is_empty() && !data.is_empty() {
                let idx = rng.below(entry.chunks.len() as u64) as usize;
                let start: usize = entry.chunks[..idx].iter().map(|c| c.raw_len).sum();
                let chunk = reader.read_chunk(name, idx).unwrap();
                assert_eq!(chunk[..], data[start..start + entry.chunks[idx].raw_len]);
                let r0 = rng.below(data.len() as u64) as usize;
                let rl = rng.below((data.len() - r0 + 1) as u64) as usize;
                assert_eq!(reader.read_range(name, r0, rl).unwrap()[..], data[r0..r0 + rl]);
            }
        }
        std::fs::remove_file(&path).ok();

        // v1 back-compat: same tensors serialized with the v1 wire still
        // open and decode through both APIs.
        let v1_path = tmppath(&format!("prop_v1_{case}"));
        std::fs::write(&v1_path, archive.serialize()).unwrap();
        let v1 = ArchiveReader::open(&v1_path).unwrap();
        assert_eq!(v1.version(), 1, "case {case}");
        for (name, data, _) in &tensors {
            assert_eq!(&v1.read_tensor(name).unwrap(), data, "case {case} v1 {name}");
        }
        let loaded = Archive::load(&v1_path).unwrap();
        assert_eq!(loaded.len(), tensors.len());
        std::fs::remove_file(&v1_path).ok();
    }
}

/// Acceptance: reading one chunk of one tensor from a v2 archive is a
/// positioned read of exactly that chunk — demonstrated by corrupting
/// every OTHER tensor's data region on disk and still reading bit-exactly.
#[test]
fn archive_v2_chunk_read_is_isolated() {
    let path = tmppath("isolated");
    let session = Compressor::new(
        CompressOptions::for_format(FloatFormat::Bf16).with_chunk_size(2048),
    );
    let a = synthetic::gaussian_bf16_bytes(8000, 0.02, 91);
    let b = synthetic::gaussian_bf16_bytes(8000, 0.02, 92);
    let c = synthetic::gaussian_bf16_bytes(8000, 0.02, 93);
    let mut writer = ArchiveWriter::create(&path).unwrap();
    for (name, data) in [("a", &a), ("b", &b), ("c", &c)] {
        let blob = session.compress(TensorInput::Tensor(data)).unwrap();
        writer
            .add(TensorMeta { name: name.into(), shape: vec![data.len() as u64] }, &blob)
            .unwrap();
    }
    writer.finish().unwrap();

    // Trash every byte of tensors `a` and `c` on disk. If read_chunk("b")
    // deserialized anything outside b's chunks, it would now fail.
    let reader = ArchiveReader::open(&path).unwrap();
    let (a_off, a_len) = {
        let e = reader.entry("a").unwrap();
        (e.data_offset, e.data_len())
    };
    let (c_off, c_len) = {
        let e = reader.entry("c").unwrap();
        (e.data_offset, e.data_len())
    };
    let b_entry = reader.entry("b").unwrap().clone();
    drop(reader);
    let mut file = std::fs::read(&path).unwrap();
    for i in a_off..a_off + a_len {
        file[i as usize] ^= 0xFF;
    }
    for i in c_off..c_off + c_len {
        file[i as usize] ^= 0xFF;
    }
    std::fs::write(&path, &file).unwrap();

    let reader = ArchiveReader::open(&path).unwrap();
    for idx in 0..b_entry.chunks.len() {
        let start: usize = b_entry.chunks[..idx].iter().map(|ch| ch.raw_len).sum();
        let chunk = reader.read_chunk("b", idx).unwrap();
        assert_eq!(
            chunk[..],
            b[start..start + b_entry.chunks[idx].raw_len],
            "chunk {idx} of untouched tensor must read bit-exactly"
        );
    }
    // And the trashed neighbours do fail loudly.
    assert!(reader.read_tensor("a").is_err());
    assert!(reader.read_tensor("c").is_err());
    std::fs::remove_file(&path).ok();
}

/// Property (tentpole acceptance): the chunk-parallel archive read path is
/// bit-identical to the serial zero-copy decode on every scalar format, at
/// every worker count 1..=4, on both backings — including the pread
/// fallback with mmap force-disabled.
#[test]
fn prop_parallel_archive_read_matches_serial_all_formats() {
    use zipnn_lp::container::ReadBacking;
    use zipnn_lp::exec::WorkerPool;
    let mut rng = Rng::new(4242);
    for format in FORMATS {
        for case in 0..3 {
            let a = align(format);
            let len = (2048 + rng.below(40_000) as usize) / a * a;
            let mut data = vec![0u8; len];
            if case % 2 == 0 {
                rng.fill_bytes(&mut data);
            } else {
                for b in data.iter_mut() {
                    *b = if rng.next_f64() < 0.8 { 0x3C } else { rng.below(256) as u8 };
                }
            }
            let session =
                Compressor::new(CompressOptions::for_format(format).with_chunk_size(4096));
            let blob = session.compress(TensorInput::Tensor(&data)).unwrap();
            // Serial reference: the session's zero-copy blob decode.
            let mut serial = vec![0u8; data.len()];
            session.decompress_into(&blob, &mut serial).unwrap();
            assert_eq!(serial, data, "{format:?} case {case} serial reference");

            let path = tmppath(&format!("par_{format:?}_{case}"));
            let mut writer = ArchiveWriter::create(&path).unwrap();
            writer
                .add(TensorMeta { name: "t".into(), shape: vec![len as u64] }, &blob)
                .unwrap();
            writer.finish().unwrap();

            for backing in [ReadBacking::Auto, ReadBacking::Pread] {
                let reader = ArchiveReader::open_with(&path, backing).unwrap();
                for workers in 1..=4usize {
                    let pool = WorkerPool::new(workers);
                    let mut out = vec![0u8; data.len()];
                    reader.read_tensor_into_pooled("t", &mut out, &pool).unwrap();
                    assert_eq!(
                        out, serial,
                        "{format:?} case {case} {backing:?} x{workers}"
                    );
                }
                // The session wrapper rides the same path.
                let s = Compressor::new(
                    CompressOptions::for_format(format).with_threads(4),
                );
                let mut out = vec![0u8; data.len()];
                s.read_tensor_into(&reader, "t", &mut out).unwrap();
                assert_eq!(out, serial, "{format:?} case {case} session wrapper");
            }
            std::fs::remove_file(&path).ok();
        }
    }
}

/// The pipelined stream decoder produces in-order, bit-exact output at
/// every thread count, with the bounded-buffer guarantee intact.
#[test]
fn pipelined_stream_decode_matches_all_thread_counts() {
    let chunk = 8 * 1024;
    let data = synthetic::gaussian_bf16_bytes(300_000, 0.02, 55);
    let enc = Compressor::new(
        CompressOptions::for_format(FloatFormat::Bf16)
            .with_chunk_size(chunk)
            .with_threads(2),
    );
    let mut wire = Vec::new();
    let esum = enc.compress_stream(&data[..], &mut wire).unwrap();
    assert!(esum.chunks > 16, "need many chunks to exercise the pipeline");
    for threads in 1..=4usize {
        let s = Compressor::new(
            CompressOptions::for_format(FloatFormat::Bf16)
                .with_chunk_size(chunk)
                .with_threads(threads),
        );
        let mut out = Vec::new();
        let sum = s.decompress_stream(&wire[..], &mut out).unwrap();
        assert_eq!(out, data, "threads={threads}: output must stay in stream order");
        assert_eq!(sum.chunks, esum.chunks);
        let window = (threads * sum.chunk_size) as u64;
        assert!(
            sum.peak_buffered <= 2 * window + 16 * 1024,
            "threads={threads}: peak {} not bounded by window {window}",
            sum.peak_buffered
        );
    }
}

/// The deprecated-style free functions still agree with the session.
#[test]
fn free_functions_remain_wire_compatible() {
    let data = synthetic::gaussian_bf16_bytes(20_000, 0.02, 99);
    let opts = CompressOptions::for_format(FloatFormat::Bf16).with_chunk_size(4096);
    let legacy = compress_tensor(&data, &opts).unwrap();
    let session = Compressor::new(opts);
    let blob = session.compress(TensorInput::Tensor(&data)).unwrap();
    assert_eq!(legacy.serialize(), blob.serialize());
    assert_eq!(zipnn_lp::codec::decompress_tensor(&legacy).unwrap(), data);
    assert_eq!(session.decompress(&blob).unwrap(), data);
}
