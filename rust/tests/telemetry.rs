//! End-to-end telemetry: drive real codec work through a `Compressor`
//! session, then check that the global registry covers the instrumented
//! subsystems and that every exporter's output round-trips through the
//! in-house JSON parser (`util::json`).
//!
//! The Chrome-trace test doubles as the span pipeline's integration check:
//! runtime toggle on, real workload, drain, schema round-trip. It is the
//! only test in this binary touching the process-global tracing switch.

use zipnn_lp::codec::{CompressOptions, Compressor, TensorInput};
use zipnn_lp::formats::FloatFormat;
use zipnn_lp::obs::{self, export};
use zipnn_lp::synthetic;
use zipnn_lp::util::json::Json;

/// A small chunk-parallel compress + zero-copy decompress round trip — the
/// same hot paths `compress`/`decompress`/`stats` exercise.
fn decode_workload() {
    let data = synthetic::gaussian_bf16_bytes(64 * 1024, 0.02, 5);
    let session = Compressor::new(
        CompressOptions::for_format(FloatFormat::Bf16)
            .with_chunk_size(8192)
            .with_threads(2),
    );
    let blob = session.compress(TensorInput::Tensor(&data)).unwrap();
    let mut out = vec![0u8; data.len()];
    session.decompress_into(&blob, &mut out).unwrap();
    assert_eq!(out, data, "telemetry workload must stay bit-exact");
}

#[test]
fn exporters_cover_instrumented_subsystems() {
    decode_workload();
    let snap = obs::global().snapshot();
    // The session and pool hot paths must have reported into the registry.
    for name in [
        "codec.compress_ns",
        "codec.decompress_ns",
        "codec.bytes_in_total",
        "exec.tasks_total",
    ] {
        assert!(snap.get(name).is_some(), "metric {name} missing from snapshot");
    }

    // Prometheus text: expected families present, every sample line valid.
    let prom = export::prometheus_text(&snap);
    assert!(prom.contains("# TYPE zipnn_codec_compress_ns summary"));
    assert!(prom.contains("# TYPE zipnn_exec_tasks_total counter"));
    for line in prom.lines().filter(|l| !l.starts_with('#')) {
        let mut parts = line.split(' ');
        assert!(parts.next().unwrap().starts_with("zipnn_"), "line: {line}");
        assert!(parts.next().unwrap().parse::<f64>().is_ok(), "line: {line}");
        assert!(parts.next().is_none(), "line: {line}");
    }

    // JSON document: parses with the in-house parser, typed fields intact.
    let doc = export::json_document(&snap);
    let j = Json::parse(&doc).unwrap();
    assert_eq!(j.field("kind").unwrap().as_str(), Some("zipnn-metrics"));
    let metrics = j.field("metrics").unwrap();
    let hist = metrics.field("codec.decompress_ns").unwrap();
    assert_eq!(hist.field("type").unwrap().as_str(), Some("histogram"));
    assert!(hist.field("count").unwrap().as_usize().unwrap() >= 1);
    let tasks = metrics.field("exec.tasks_total").unwrap();
    assert_eq!(tasks.field("type").unwrap().as_str(), Some("counter"));
}

#[cfg(feature = "telemetry")]
#[test]
fn trace_round_trips_through_chrome_schema() {
    obs::set_tracing(true);
    decode_workload();
    obs::set_tracing(false);
    let events = obs::take_events();
    let names: Vec<&str> = events.iter().map(|e| e.name).collect();
    assert!(names.contains(&"codec.compress"), "spans: {names:?}");
    assert!(names.contains(&"codec.decompress"), "spans: {names:?}");
    assert!(names.contains(&"codec.decode_chunk"), "spans: {names:?}");

    let doc = export::chrome_trace(&events);
    let j = Json::parse(&doc).unwrap();
    assert_eq!(j.field("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let rows = j.field("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), events.len());
    for row in rows {
        assert_eq!(row.field("ph").unwrap().as_str(), Some("X"));
        assert_eq!(row.field("cat").unwrap().as_str(), Some("zipnn"));
        assert_eq!(row.field("pid").unwrap().as_usize(), Some(1));
        assert!(row.field("name").unwrap().as_str().is_some());
        assert!(row.field("ts").unwrap().as_f64().is_some());
        assert!(row.field("dur").unwrap().as_f64().is_some());
    }
}

#[cfg(not(feature = "telemetry"))]
#[test]
fn tracing_is_compiled_out() {
    // With the feature off the switch is inert, no events exist, and the
    // metric registry still works (metrics are feature-independent).
    obs::set_tracing(true);
    decode_workload();
    assert!(!obs::tracing_enabled());
    assert!(obs::take_events().is_empty());
    assert!(obs::global().snapshot().get("codec.compress_ns").is_some());
}
